"""SDC guard end-to-end + unit coverage (docs/sdc.md).

The acceptance loop: inject a bit-flip mid-run -> a detection tier names
it -> run_with_recovery rolls back to the last checksum-verified
checkpoint -> training reconverges bit-exactly with the uninterrupted
reference run.
"""
import glob
import os

import jax
import numpy as np
import pytest

from repro.core import (CheckpointManager, CorruptionDetected, Dependability,
                        DependabilityConfig, FaultInjector, flip_bit,
                        run_with_recovery)
from repro.data import make_pipeline
from repro.models import get_config
from repro.sdc import LossSentinel, StateScrubber, leaf_checksum, named_leaves
from repro.train import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _dep(tmp_path, **kw):
    base = dict(policy_mode="every_n", every_n=2, heartbeat=False,
                signal_detection=False)
    base.update(kw)
    return Dependability(DependabilityConfig(checkpoint_dir=str(tmp_path),
                                             **base)).start()


def _run_reference(cfg, steps):
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    state = init_state(cfg, KEY)
    data = make_pipeline(cfg, 16, 4)
    for _ in range(steps):
        state, m = step_fn(state, data.next_batch())
    return state, float(m["loss"])


def _param_leaf(state, contains):
    return [n for n, _ in named_leaves(state)
            if n.startswith("params.") and contains in n][0]


# ---------------------------------------------------------------------------
# bit-flip injection
# ---------------------------------------------------------------------------

def test_flip_bit_is_a_deterministic_involution():
    x = jax.random.normal(KEY, (4, 8))
    y = flip_bit(x, 30)
    assert not np.array_equal(np.asarray(x), np.asarray(y))
    # exactly one element differs, and flipping again restores the original
    assert int(np.sum(np.asarray(x) != np.asarray(y))) == 1
    z = flip_bit(y, 30)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


def test_flip_bit_range_checked():
    x = jax.random.normal(KEY, (2, 2))
    with pytest.raises(IndexError):
        flip_bit(x, 2 * 2 * 4 * 8)


def test_injector_applies_scheduled_flip_once():
    inj = FaultInjector()
    inj.schedule_bitflip(3, "a.b", 5)
    state = {"a": {"b": jax.random.normal(KEY, (16,))}, "c": np.arange(4)}
    same = inj.apply_sdc(2, state)
    assert same is state                       # nothing due at step 2
    hit = inj.apply_sdc(3, state)
    assert not np.array_equal(np.asarray(hit["a"]["b"]),
                              np.asarray(state["a"]["b"]))
    np.testing.assert_array_equal(np.asarray(hit["c"]), state["c"])
    assert inj.sdc_injected == [(3, "a.b", 5)]
    again = inj.apply_sdc(3, hit)              # popped: applies only once
    assert again is hit


def test_injector_unknown_leaf_raises():
    inj = FaultInjector()
    inj.schedule_bitflip(1, "nope", 0)
    with pytest.raises(KeyError):
        inj.apply_sdc(1, {"a": np.zeros(4)})


# ---------------------------------------------------------------------------
# tier 2: state scrubber
# ---------------------------------------------------------------------------

def test_leaf_checksum_detects_single_bit_flip():
    for shape in [(64,), (3, 5)]:
        x = jax.random.normal(KEY, shape)
        for bit in (0, 17, 30, 31):
            assert leaf_checksum(x) != leaf_checksum(flip_bit(x, bit))


def test_scrubber_pinpoints_corrupted_leaf():
    state = {"p": {"w1": jax.random.normal(KEY, (32,)),
                   "w2": jax.random.normal(jax.random.fold_in(KEY, 1), (32,))},
             "step": np.int32(7)}
    scr = StateScrubber(fraction=1.0)
    scr.record(state, step=0)
    assert scr.verify(state) == []             # untouched state is clean
    bad = dict(state, p=dict(state["p"], w2=flip_bit(state["p"]["w2"], 40)))
    assert scr.verify(bad) == ["p.w2"]


def test_scrubber_rotation_covers_all_leaves():
    state = {f"w{i}": np.full((4,), float(i), np.float32) for i in range(8)}
    scr = StateScrubber(fraction=0.25)         # 2 of 8 leaves per record
    seen = set()
    for s in range(4):
        seen.update(scr.record(state, s))
    assert len(seen) == 8                      # full sweep after 1/f steps
    assert scr.leaves_scrubbed == 8


def test_scrubber_reset_clears_window():
    state = {"w": jax.random.normal(KEY, (16,))}
    scr = StateScrubber(fraction=1.0)
    scr.record(state, 0)
    scr.reset()
    # a "different" state verifies clean: no stale window to compare against
    assert scr.verify({"w": flip_bit(state["w"], 3)}) == []


# ---------------------------------------------------------------------------
# tier 3: loss sentinel
# ---------------------------------------------------------------------------

def test_sentinel_trips_on_nonfinite():
    s = LossSentinel(warmup=0)
    assert s.observe(1, 1.0) is None
    assert "non-finite" in s.observe(2, float("nan"))
    assert "non-finite" in s.observe(3, 1.0, grad_norm=float("inf"))
    assert "non-finite" in s.observe(4, 1.0, nonfinite=1.0)


def test_sentinel_trips_on_spike_and_keeps_ema_clean():
    s = LossSentinel(spike_factor=10.0, warmup=2)
    for i in range(4):
        assert s.observe(i, 2.0) is None
    ema_before = s.loss_ema
    assert "spike" in s.observe(5, 2000.0)
    assert s.loss_ema == ema_before            # anomaly never enters the EMA
    assert s.observe(6, 2.1) is None           # replayed healthy step passes
    assert s.trips == 1


def test_sentinel_warmup_suppresses_spike():
    s = LossSentinel(spike_factor=2.0, warmup=10)
    assert s.observe(0, 1.0) is None
    assert s.observe(1, 100.0) is None         # still warming up


# ---------------------------------------------------------------------------
# restore walk-back (satellite: CRC-mismatch fallback)
# ---------------------------------------------------------------------------

def _corrupt_a_shard(ckpt_dir, step):
    [shard] = glob.glob(os.path.join(ckpt_dir, f"step_{step:08d}",
                                     "p.w*.npy"))[:1]
    with open(shard, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        last = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([last[0] ^ 0xFF]))


def test_restore_latest_walks_back_past_corrupt_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"p": {"w": jax.random.normal(KEY, (128,))}}
    states = {}
    for s in (2, 4, 6):
        state = {"p": {"w": state["p"]["w"] + 1.0}}
        states[s] = np.asarray(state["p"]["w"])
        mgr.save(s, state)
    _corrupt_a_shard(str(tmp_path), 6)
    got, local, step, skipped = mgr.restore_latest(like=state)
    assert step == 4
    assert [s for s, _ in skipped] == [6]
    assert "CRC" in skipped[0][1]
    np.testing.assert_array_equal(np.asarray(got["p"]["w"]), states[4])
    mgr.close()


def test_restore_latest_all_corrupt_raises_with_detail(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"p": {"w": jax.random.normal(KEY, (128,))}}
    for s in (1, 2):
        mgr.save(s, state)
        _corrupt_a_shard(str(tmp_path), s)
    with pytest.raises(FileNotFoundError, match="skipped"):
        mgr.restore_latest(like=state)
    mgr.close()


def test_dependability_restore_surfaces_skipped(tmp_path):
    dep = _dep(tmp_path)
    state = {"p": {"w": jax.random.normal(KEY, (128,))}}
    dep.save(2, state)
    dep.save(4, state)
    _corrupt_a_shard(str(tmp_path), 4)
    got, step = dep.restore_latest(like=state)
    assert step == 2
    assert [s for s, _ in dep.last_restore_skipped] == [4]
    dep.stop()


# ---------------------------------------------------------------------------
# end-to-end: inject -> detect -> rollback -> reconverge
# ---------------------------------------------------------------------------

def test_scrub_detects_bitflip_and_recovery_reconverges(tmp_path):
    cfg = get_config("granite-3-8b", tiny=True)
    steps = 9
    ref_state, ref_loss = _run_reference(cfg, steps)

    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    state = init_state(cfg, KEY)
    leaf = _param_leaf(state, "attn.wk")
    data = make_pipeline(cfg, 16, 4)
    dep = _dep(tmp_path, scrub=True, scrub_fraction=1.0)
    dep.register_local_state(data)
    injector = FaultInjector()
    injector.schedule_bitflip(5, leaf, bit=30)
    state, info = run_with_recovery(dep, step_fn, state, data, steps,
                                    fault_injector=injector, like=state,
                                    max_restarts=3)
    assert info["status"] == "done"
    assert info["restarts"] == 1
    events = [h["event"] for h in info["history"] if "event" in h]
    # the scrubber pinpoints the corrupted leaf by name
    assert events == [f"corruption:scrub:{leaf}"]
    # rollback went to a scrub-verified checkpoint
    assert dep.verified_steps
    # reconvergence is bit-exact with the uninterrupted run
    last_loss = [h["loss"] for h in info["history"] if "loss" in h][-1]
    assert last_loss == ref_loss
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    dep.stop()


def test_repeat_corruption_walks_back_past_suspect_checkpoint(tmp_path):
    """When corruption re-trips after a rollback with no new checkpoint in
    between, the checkpoint recovery rolled back to is suspect (a flip the
    scrubber missed before the save has CRCs that verify fine) — recovery
    must exclude it and walk one checkpoint further back instead of
    livelocking on it until max_restarts."""
    cfg = get_config("granite-3-8b", tiny=True)
    steps = 9
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    state = init_state(cfg, KEY)
    leaf = _param_leaf(state, "attn.wq")
    data = make_pipeline(cfg, 16, 4)
    dep = _dep(tmp_path, scrub=True, scrub_fraction=1.0)
    dep.register_local_state(data)
    # flip at 5 -> detected, rollback to ckpt@4, replay; flip at 6 ->
    # detected again before any new checkpoint: ckpt@4 is now suspect and
    # excluded, so the second rollback must restore ckpt@2
    injector = FaultInjector()
    injector.schedule_bitflip(5, leaf, bit=30)
    injector.schedule_bitflip(6, leaf, bit=31)
    state, info = run_with_recovery(dep, step_fn, state, data, steps,
                                    fault_injector=injector, like=state,
                                    max_restarts=4)
    assert info["status"] == "done"
    assert info["restarts"] == 2
    events = [h["event"] for h in info["history"] if "event" in h]
    assert len(events) == 2
    assert all(ev.startswith("corruption:scrub:") for ev in events)
    # restored from ckpt@2 the second time (ckpt@4 excluded): the replay
    # after the last corruption event starts at step 3
    replayed = [h["step"] for h in info["history"] if "loss" in h]
    assert replayed[0] == 3
    # the run reconverges to the reference despite the double hit
    _, ref_loss = _run_reference(cfg, steps)
    last_loss = [h["loss"] for h in info["history"] if "loss" in h][-1]
    assert last_loss == ref_loss
    dep.stop()


def test_sentinel_catches_unscrubbed_flip_and_recovers(tmp_path):
    """Corruption in a leaf the scrubber never covers still gets caught by
    the tier-3 sentinel (non-finite loss) and rolled back."""
    cfg = get_config("granite-3-8b", tiny=True)
    steps = 8
    ref_state, ref_loss = _run_reference(cfg, steps)

    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    state = init_state(cfg, KEY)
    leaf = _param_leaf(state, "final_norm")    # bit 30 -> NaN loss
    data = make_pipeline(cfg, 16, 4)
    dep = _dep(tmp_path, sentinel=True, sentinel_warmup=2)
    dep.register_local_state(data)
    injector = FaultInjector()
    injector.schedule_bitflip(5, leaf, bit=30)
    state, info = run_with_recovery(dep, step_fn, state, data, steps,
                                    fault_injector=injector, like=state,
                                    max_restarts=3)
    assert info["status"] == "done"
    assert info["restarts"] == 1
    events = [h["event"] for h in info["history"] if "event" in h]
    assert len(events) == 1 and events[0].startswith("corruption:sentinel:")
    last_loss = [h["loss"] for h in info["history"] if "loss" in h][-1]
    assert last_loss == ref_loss
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    dep.stop()
