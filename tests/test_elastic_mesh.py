"""Elastic recovery + mesh sharding tests.

These need multiple devices, so each test runs a subprocess with
--xla_force_host_platform_device_count set (the main test process must keep
the default single CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(script: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


def test_elastic_reshard_restore(tmp_path):
    """Train on a (2,2) mesh, checkpoint, 'lose' 4 devices, restore onto a
    (1,2) survivor mesh and keep training — trajectory must match a run
    that never failed."""
    _run(f"""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import CheckpointManager, survivor_mesh, reshard_state
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.sharding.api import mesh_context, resolve
    from repro.sharding.rules import state_specs
    from repro.train import init_state, make_train_step
    import jax.numpy as jnp

    cfg = get_config("granite-3-8b", tiny=True)
    key = jax.random.PRNGKey(0)

    def sharded_state(mesh, tp):
        specs = state_specs(cfg, tp)
        sh = jax.tree.map(lambda s: resolve(s, mesh), specs,
                          is_leaf=lambda x: x.__class__.__name__ == "PartitionSpec")
        return sh

    # reference: single-device run, 6 steps
    step = jax.jit(make_train_step(cfg, total_steps=10))
    ref = init_state(cfg, key)
    data = make_pipeline(cfg, 16, 4)
    for _ in range(6):
        ref, m = step(ref, data.next_batch())
    ref_loss = float(m["loss"])

    # mesh A: (2 data, 2 model); 3 steps then checkpoint
    mesh_a = jax.make_mesh((2, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh_a = sharded_state(mesh_a, 2)
    data2 = make_pipeline(cfg, 16, 4)
    with mesh_context(mesh_a):
        st = jax.jit(lambda: init_state(cfg, key), out_shardings=sh_a)()
        step_a = jax.jit(make_train_step(cfg, total_steps=10),
                         out_shardings=(sh_a, None))
        for _ in range(3):
            st, _ = step_a(st, data2.next_batch())
    mgr = CheckpointManager(r"{tmp_path}")
    mgr.save(3, st, data2.state_dict())

    # 'failure': only 2 devices survive -> (1 data, 2 model) mesh
    surv = survivor_mesh(list(jax.devices())[:2], model_axis=2)
    template = jax.eval_shape(lambda: init_state(cfg, key))
    st2, local, got = reshard_state(mgr, cfg, surv, template)
    assert got == 3
    data3 = make_pipeline(cfg, 16, 4)
    data3.load_state_dict(local)
    sh_b = sharded_state(surv, 2)
    with mesh_context(surv):
        step_b = jax.jit(make_train_step(cfg, total_steps=10),
                         out_shardings=(sh_b, None))
        for _ in range(3):
            st2, m2 = step_b(st2, data3.next_batch())
    got_loss = float(m2["loss"])
    # bf16 cross-shard reduction order differs between mesh layouts;
    # trajectories agree to ~1e-3 after 6 steps
    assert abs(got_loss - ref_loss) < 5e-3, (got_loss, ref_loss)
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
    print("elastic reshard OK", ref_loss, got_loss)
    """, devices=8)


def test_sharded_training_matches_single_device(tmp_path):
    """(2 data, 2 model) training == single-device training (same seeds)."""
    _run("""
    import jax, numpy as np
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.sharding.api import mesh_context, resolve
    from repro.sharding.rules import state_specs
    from repro.train import init_state, make_train_step

    cfg = get_config("mixtral-8x7b", tiny=True)
    key = jax.random.PRNGKey(0)
    step = jax.jit(make_train_step(cfg, total_steps=10))
    ref = init_state(cfg, key)
    data = make_pipeline(cfg, 16, 4)
    for _ in range(4):
        ref, m = step(ref, data.next_batch())
    ref_loss = float(m["loss"])

    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    specs = state_specs(cfg, 2)
    sh = jax.tree.map(lambda s: resolve(s, mesh), specs,
                      is_leaf=lambda x: x.__class__.__name__ == "PartitionSpec")
    data2 = make_pipeline(cfg, 16, 4)
    with mesh_context(mesh):
        st = jax.jit(lambda: init_state(cfg, key), out_shardings=sh)()
        step_m = jax.jit(make_train_step(cfg, total_steps=10,
                                         param_specs=specs["params"]),
                         out_shardings=(sh, None))
        for _ in range(4):
            st, m2 = step_m(st, data2.next_batch())
    got = float(m2["loss"])
    assert abs(got - ref_loss) < 5e-3, (got, ref_loss)
    print("sharded == single", ref_loss, got)
    """, devices=4)


def test_dryrun_single_cell_compiles():
    """End-to-end proof on the real 512-device production mesh (slow)."""
    _run("""
    from repro.launch.dryrun import run_cell
    rec = run_cell("gemma-7b", "train_4k", multi_pod=True)
    assert rec["status"] == "ok", rec
    print("multi-pod cell ok:", rec["cost"]["flops_per_device"])
    """, devices=512, timeout=900)


def test_largest_grid():
    from repro.core import largest_grid
    assert largest_grid(8, 2) == (4, 2)
    assert largest_grid(6, 4) == (2, 3)   # model shrinks to a divisor
    assert largest_grid(5, 2) == (5, 1)
