"""Elastic recovery + mesh sharding tests.

These need multiple devices, so each test runs a subprocess with
--xla_force_host_platform_device_count set (the main test process must keep
the default single CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(script: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_elastic_reshard_restore(tmp_path):
    """Train on a (2,2) mesh, checkpoint, 'lose' 4 devices, restore onto a
    (1,2) survivor mesh and keep training — trajectory must match a run
    that never failed."""
    _run(f"""
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.core import CheckpointManager, survivor_mesh, reshard_state
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.sharding.api import mesh_context, resolve
    from repro.sharding.rules import state_specs
    from repro.train import init_state, make_train_step
    import jax.numpy as jnp

    cfg = get_config("granite-3-8b", tiny=True)
    key = jax.random.PRNGKey(0)

    def sharded_state(mesh, tp):
        specs = state_specs(cfg, tp)
        sh = jax.tree.map(lambda s: resolve(s, mesh), specs,
                          is_leaf=lambda x: x.__class__.__name__ == "PartitionSpec")
        return sh

    # reference: single-device run, 6 steps
    step = jax.jit(make_train_step(cfg, total_steps=10))
    ref = init_state(cfg, key)
    data = make_pipeline(cfg, 16, 4)
    for _ in range(6):
        ref, m = step(ref, data.next_batch())
    ref_loss = float(m["loss"])

    # mesh A: (2 data, 2 model); 3 steps then checkpoint
    from repro.launch.mesh import make_mesh_compat
    mesh_a = make_mesh_compat((2, 2), ("data", "model"))
    sh_a = sharded_state(mesh_a, 2)
    data2 = make_pipeline(cfg, 16, 4)
    with mesh_context(mesh_a):
        st = jax.jit(lambda: init_state(cfg, key), out_shardings=sh_a)()
        step_a = jax.jit(make_train_step(cfg, total_steps=10),
                         out_shardings=(sh_a, None))
        for _ in range(3):
            st, _ = step_a(st, data2.next_batch())
    mgr = CheckpointManager(r"{tmp_path}")
    mgr.save(3, st, data2.state_dict())

    # 'failure': only 2 devices survive -> (1 data, 2 model) mesh
    surv = survivor_mesh(list(jax.devices())[:2], model_axis=2)
    template = jax.eval_shape(lambda: init_state(cfg, key))
    st2, local, got = reshard_state(mgr, cfg, surv, template)
    assert got == 3
    # the resharded restore itself must be BIT-EXACT vs the saved state
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "restore differs"
    data3 = make_pipeline(cfg, 16, 4)
    data3.load_state_dict(local)
    sh_b = sharded_state(surv, 2)
    with mesh_context(surv):
        step_b = jax.jit(make_train_step(cfg, total_steps=10),
                         out_shardings=(sh_b, None))
        for _ in range(3):
            st2, m2 = step_b(st2, data3.next_batch())
    got_loss = float(m2["loss"])
    # bf16 cross-shard reduction order differs between mesh layouts and
    # compounds over steps: individual params drift while the losses stay
    # close; on this XLA/CPU version trajectories agree to ~1.6% after 6
    # steps (a broken restore lands ~order 1 off).  The restore itself is
    # checked bit-exact above.
    assert abs(got_loss - ref_loss) < 0.15, (got_loss, ref_loss)
    print("elastic reshard OK", ref_loss, got_loss)
    """, devices=8)


@pytest.mark.slow
def test_restore_onto_different_shard_layout(tmp_path):
    """Save shards on a (4,2) mesh, restore bit-exact onto a (2,1) mesh
    with different partition axes AND onto plain numpy — spans reassembly,
    multi-shard parallel reads, and the device-codec path."""
    _run(f"""
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import CheckpointManager
    from repro.launch.mesh import make_mesh_compat

    mesh_a = make_mesh_compat((4, 2), ("data", "model"))
    mesh_b = make_mesh_compat((2, 1), ("data", "model"))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 6, 128), jnp.float32)
    y = jnp.arange(512, dtype=jnp.int32)
    state = {{
        "x": jax.device_put(x, NamedSharding(mesh_a, P("data", "model"))),
        "y": jax.device_put(y, NamedSharding(mesh_a, P("data"))),
        "s": jnp.asarray(3, jnp.int32),
    }}
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        state)
    sh_b = {{
        "x": NamedSharding(mesh_b, P("model", "data")),  # different axes!
        "y": NamedSharding(mesh_b, P(None)),
        "s": NamedSharding(mesh_b, P()),
    }}

    # raw codec: restore must be bit-exact
    d = r"{tmp_path}" + "/raw"
    mgr = CheckpointManager(d, io_threads=4)
    mgr.save(1, state)
    r, _ = mgr.restore(like=like, shardings=sh_b)
    assert np.array_equal(np.asarray(r["x"]), np.asarray(x))
    assert np.array_equal(np.asarray(r["y"]), np.asarray(y))
    assert int(r["s"]) == 3
    r2, _ = mgr.restore()  # numpy (no template) restore, same bytes
    assert np.array_equal(r2["x"], np.asarray(x))

    # device codec: restore within quantization tolerance, same layout rules
    d2 = r"{tmp_path}" + "/dev"
    mgr2 = CheckpointManager(d2, device_codec=True)
    mgr2.save(1, state)
    r3, _ = mgr2.restore(like=like, shardings=sh_b)
    w0, w1 = np.asarray(x), np.asarray(r3["x"])
    assert w1.shape == w0.shape
    assert np.abs(w0 - w1).max() <= np.abs(w0).max() / 127.0 * 0.51 + 1e-6
    assert np.array_equal(np.asarray(r3["y"]), np.asarray(y))  # ints exact
    print("cross-layout restore OK")
    """, devices=8)


@pytest.mark.slow
def test_sharded_training_matches_single_device(tmp_path):
    """(2 data, 2 model) training == single-device training (same seeds)."""
    _run("""
    import jax, numpy as np
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.sharding.api import mesh_context, resolve
    from repro.sharding.rules import state_specs
    from repro.train import init_state, make_train_step

    cfg = get_config("mixtral-8x7b", tiny=True)
    key = jax.random.PRNGKey(0)
    step = jax.jit(make_train_step(cfg, total_steps=10))
    ref = init_state(cfg, key)
    data = make_pipeline(cfg, 16, 4)
    for _ in range(4):
        ref, m = step(ref, data.next_batch())
    ref_loss = float(m["loss"])

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((2, 2), ("data", "model"))
    specs = state_specs(cfg, 2)
    sh = jax.tree.map(lambda s: resolve(s, mesh), specs,
                      is_leaf=lambda x: x.__class__.__name__ == "PartitionSpec")
    data2 = make_pipeline(cfg, 16, 4)
    with mesh_context(mesh):
        st = jax.jit(lambda: init_state(cfg, key), out_shardings=sh)()
        step_m = jax.jit(make_train_step(cfg, total_steps=10,
                                         param_specs=specs["params"]),
                         out_shardings=(sh, None))
        for _ in range(4):
            st, m2 = step_m(st, data2.next_batch())
    got = float(m2["loss"])
    # bf16 reduction-order noise between mesh layouts; measured ~1.4e-2
    # on this XLA/CPU version after 4 steps
    assert abs(got - ref_loss) < 5e-2, (got, ref_loss)
    print("sharded == single", ref_loss, got)
    """, devices=4)


@pytest.mark.slow
def test_dryrun_single_cell_compiles():
    """End-to-end proof on the real 512-device production mesh (slow)."""
    _run("""
    from repro.launch.dryrun import run_cell
    rec = run_cell("gemma-7b", "train_4k", multi_pod=True)
    assert rec["status"] == "ok", rec
    print("multi-pod cell ok:", rec["cost"]["flops_per_device"])
    """, devices=512, timeout=900)


def test_largest_grid():
    from repro.core import largest_grid
    assert largest_grid(8, 2) == (4, 2)
    assert largest_grid(6, 4) == (2, 3)   # model shrinks to a divisor
    assert largest_grid(5, 2) == (5, 1)


def test_largest_grid_no_survivors_is_a_clear_error():
    from repro.core import NoSurvivorsError, largest_grid
    with pytest.raises(NoSurvivorsError):
        largest_grid(0, 2)                # used to be ZeroDivisionError
    with pytest.raises(NoSurvivorsError):
        largest_grid(-1, 1)


def test_survivor_mesh_fraction_and_empty():
    """A float failed fraction excludes round(f * n) devices (0.5 really
    halves the fleet) and losing everything raises NoSurvivorsError."""
    _run("""
    import jax, pytest
    from repro.core import NoSurvivorsError, survivor_mesh

    n = len(jax.devices())
    assert n == 8
    m = survivor_mesh(0.5, model_axis=2)          # half the devices fail
    assert m.devices.size == 4, m.devices.shape
    m = survivor_mesh(0.25, model_axis=2)
    assert m.devices.size == 6                    # 8 - round(2)
    m = survivor_mesh(2, model_axis=2)            # int: a device count
    assert m.devices.size == 6
    try:
        survivor_mesh(8, model_axis=2)            # all failed
        raise SystemExit("expected NoSurvivorsError")
    except NoSurvivorsError:
        pass
    try:
        survivor_mesh([], model_axis=2)           # empty explicit list
        raise SystemExit("expected NoSurvivorsError")
    except NoSurvivorsError:
        pass
    print("survivor_mesh fraction OK")
    """, devices=8)


def test_rescale_global_batch_keeps_per_replica_constant():
    from repro.core import rescale_global_batch
    # shrink: 8 DP -> 6 DP, per-replica 4 stays constant
    assert rescale_global_batch(32, 8, 6) == 24
    # grow: 6 DP -> 8 DP
    assert rescale_global_batch(24, 6, 8) == 32
    # round trip is lossless (the old code rounded the global batch down)
    assert rescale_global_batch(rescale_global_batch(32, 8, 6), 6, 8) == 32
    with pytest.raises(ValueError):
        rescale_global_batch(30, 8, 6)    # 30 doesn't divide over 8
    with pytest.raises(ValueError):
        rescale_global_batch(32, 8, 0)


def test_largest_grid_legal_widths_regression():
    """Satellite regression: `model = min(model_axis, n)` used to pick a
    width that divides nothing; the legal-divisor form must degrade to the
    widest LEGAL divisor and raise a clear error when none exists."""
    from repro.core import NoLegalGridError, largest_grid
    # degrade to the largest divisor in the legal set
    assert largest_grid(8, 4, legal=(1, 2, 4)) == (2, 4)
    assert largest_grid(6, 4, legal=(1, 2)) == (3, 2)
    assert largest_grid(5, 4, legal=(1, 2, 4)) == (5, 1)
    # no legal width divides n -> error, never a silently-broken grid
    with pytest.raises(NoLegalGridError, match="no legal width divides 5"):
        largest_grid(5, 4, legal=(2, 4))
    with pytest.raises(NoLegalGridError):
        largest_grid(8, 4, legal=())      # empty legal set


def test_rescale_global_batch_3d_oracle_sweep():
    """Satellite oracle: per-replica batch is a function of dp width ONLY.
    Sweeping (dp, tp, ep) grids, rescaling between any two grids with the
    same dp is the identity, and between different dp widths preserves the
    per-replica batch — tp/ep must never leak into the scaling (the
    total-device-count bug this satellite fixes)."""
    from repro.core import rescale_global_batch
    grids = [(dp, tp, ep) for dp in (1, 2, 4, 8)
             for tp in (1, 2, 4) for ep in (1, 2)]
    per_replica = 4
    for (dp0, tp0, ep0) in grids:
        gb0 = per_replica * dp0
        for (dp1, tp1, ep1) in grids:
            got = rescale_global_batch(gb0, dp0, dp1)
            assert got == per_replica * dp1, ((dp0, tp0, ep0),
                                              (dp1, tp1, ep1), got)
            # identity whenever dp is unchanged, whatever tp/ep did
            if dp0 == dp1:
                assert got == gb0


def test_rescale_global_batch_for_mesh_reads_dp_axis():
    """The mesh-aware wrapper reads the "data" axis width off the mesh
    itself, so a 3D mesh's model/expert axes cannot skew the batch."""
    _run("""
    import jax
    from repro.core import (MeshSpec, rescale_global_batch_for_mesh,
                            survivor_mesh3d)

    spec = MeshSpec(data=4, model=2, expert=1, legal_model=(1, 2))
    m_a = survivor_mesh3d(jax.devices(), spec)            # (4, 2, 1)
    spec_b = MeshSpec(data=2, model=2, expert=2, legal_model=(1, 2),
                      num_experts=8)
    m_b = survivor_mesh3d(jax.devices(), spec_b)          # (2, 2, 2)
    # 8 devices either way; dp differs (4 vs 2): batch follows dp alone
    assert rescale_global_batch_for_mesh(16, m_a, m_b) == 8
    assert rescale_global_batch_for_mesh(8, m_b, m_a) == 16
    # same dp, ep folded away: identity
    spec_c = MeshSpec(data=2, model=2, expert=1, legal_model=(1, 2))
    m_c = survivor_mesh3d(jax.devices()[:4], spec_c)      # (2, 2, 1)
    assert rescale_global_batch_for_mesh(8, m_b, m_c) == 8
    print("rescale_for_mesh OK")
    """, devices=8)
