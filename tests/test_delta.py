"""Incremental (delta) checkpointing: dirty-block detection, chain
restore, bit-exactness vs the full-save oracle across codec configs,
corrupt-parent invalidation, chain-aware GC, and the amortized policy C.

Small ``delta_block`` values (multiples of the 256-element codec block)
keep the states tiny; the kernel path itself is swept against its numpy
oracle in tests/test_kernels.py.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager

KEY = jax.random.PRNGKey(11)


def _trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def _state(bump_block=None, base=None):
    """~4000-element leaf (16 blocks @256) + 2000-element leaf + scalar."""
    st = base or {"w": jax.random.normal(KEY, (40, 100)),
                  "b": jax.random.normal(jax.random.fold_in(KEY, 1), (2000,)),
                  "step": jnp.asarray(0, jnp.int32)}
    if bump_block is not None:
        w = np.asarray(st["w"]).reshape(-1).copy()
        w[bump_block * 256] += 3.0
        st = {**st, "w": jnp.asarray(w).reshape(40, 100),
              "step": st["step"] + 1}
    return st


def _manifest(tmp_path, step):
    p = os.path.join(str(tmp_path), f"step_{step:08d}", "manifest_h0.json")
    with open(p) as f:
        return json.load(f)


def test_first_save_is_full_then_deltas(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = _state()
    s1 = mgr.save(1, st)
    assert s1.kind == "full"
    st2 = _state(bump_block=3, base=st)
    s2 = mgr.save(2, st2)
    assert s2.kind == "delta"
    # only the touched w-block and the bumped scalar moved; b stayed clean
    man = _manifest(tmp_path, 2)
    wd = man["arrays"]["w"]["shards"][0]["delta"]
    assert wd["local"] == [3]
    assert sorted(int(b) for bs in wd["parents"].values()
                  for b in bs) == [b for b in range(16) if b != 3]
    bd = man["arrays"]["b"]["shards"][0]["delta"]
    assert bd["local"] == []                       # pure reference, no file
    assert man["arrays"]["b"]["shards"][0]["file"] is None
    assert s2.bytes_written < s1.bytes_written / 4


def test_delta_steady_state_writes_shrink(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = _state()
    full = mgr.save(1, st)
    st = _state(bump_block=5, base=st)
    delta = mgr.save(2, st)
    assert delta.dirty_blocks < delta.total_blocks
    assert delta.bytes_written < full.bytes_written / 4


@pytest.mark.parametrize("codec_kw", [
    dict(), dict(codec="int8"), dict(device_codec=True),
])
def test_delta_restore_bit_exact_vs_full_oracle(tmp_path, codec_kw):
    """full -> delta -> delta must restore BIT-IDENTICAL to a one-shot
    full save of the final state under the same codec config."""
    mgr = CheckpointManager(str(tmp_path / "delta"), delta=True,
                            delta_block=256, full_every=100, **codec_kw)
    st = _state()
    mgr.save(1, st)
    st = _state(bump_block=2, base=st)
    mgr.save(2, st)
    st = _state(bump_block=9, base=st)
    mgr.save(3, st)
    restored, _ = mgr.restore(step=3, like=st)

    oracle = CheckpointManager(str(tmp_path / "full"), **codec_kw)
    oracle.save(3, st)
    expect, _ = oracle.restore(step=3, like=st)
    assert _trees_equal(restored, expect)


def test_fresh_manager_restores_chain_and_saves_full(tmp_path):
    """Restore needs only the manifests (no in-memory base); and after a
    restore/restart the next save is a full one — delta references into
    pre-rollback steps would be meaningless."""
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = _state()
    mgr.save(1, st)
    st = _state(bump_block=7, base=st)
    mgr.save(2, st)

    mgr2 = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                             full_every=100)
    restored, _, got, skipped = mgr2.restore_latest(like=st)
    assert got == 2 and not skipped
    assert _trees_equal(restored, st)
    s3 = mgr2.save(3, st)
    assert s3.kind == "full"


def test_corrupt_parent_invalidates_every_dependent_delta(tmp_path):
    """Corrupting a mid-chain parent must walk restore_latest back past
    ALL deltas that reference it, surfaced in ``skipped``."""
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100, keep=10)
    st = _state()
    mgr.save(1, st)                      # full
    st = _state(bump_block=1, base=st)
    mgr.save(2, st)                      # delta <- 1
    st3 = _state(bump_block=8, base=st)
    mgr.save(3, st3)                     # delta <- 1, 2 (block 1 lives at 2)
    f2 = next(f for f in os.listdir(tmp_path / "step_00000002")
              if f.startswith("w.s"))
    p = tmp_path / "step_00000002" / f2
    raw = bytearray(p.read_bytes())
    raw[-1] ^= 0xFF
    p.write_bytes(bytes(raw))
    restored, _, got, skipped = mgr.restore_latest(like=st)
    assert got == 1
    assert [s for s, _ in skipped] == [3, 2]
    assert any("CRC" in r for _, r in skipped)
    assert _trees_equal(restored, _state())


def test_corrupt_full_parent_skips_to_previous_full_chain(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=2, keep=10)
    st1 = _state()
    mgr.save(1, st1)                     # full
    st2 = _state(bump_block=4, base=st1)
    mgr.save(2, st2)                     # delta <- 1
    st3 = _state(bump_block=6, base=st2)
    assert mgr.save(3, st3).kind == "full"   # full_every=2 forces a full
    st4 = _state(bump_block=11, base=st3)
    mgr.save(4, st4)                     # delta <- 3
    f3 = next(f for f in os.listdir(tmp_path / "step_00000003")
              if f.startswith("w.s"))
    p = tmp_path / "step_00000003" / f3
    raw = bytearray(p.read_bytes())
    raw[10] ^= 0xFF
    p.write_bytes(bytes(raw))
    restored, _, got, skipped = mgr.restore_latest(like=st1)
    assert got == 2                      # whole 3<-4 chain invalidated
    assert [s for s, _ in skipped] == [4, 3]
    assert _trees_equal(restored, st2)


def test_full_every_bounds_chain_depth(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=3, keep=20)
    st = _state()
    kinds = []
    for s in range(1, 8):
        kinds.append(mgr.save(s, st).kind)
        st = _state(bump_block=s % 16, base=st)
    assert kinds == ["full", "delta", "delta",
                     "full", "delta", "delta", "full"]


def test_gc_keeps_parents_of_retained_deltas(tmp_path):
    """A parent outlives ``keep`` while any retained delta references it;
    once two fresh fulls displace the chain, the old steps fall away."""
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100, keep=2)
    st = _state()
    mgr.save(1, st)
    for s in (2, 3, 4, 5):
        st = _state(bump_block=s, base=st)
        mgr.save(s, st)
    # keep=2 retains {4,5}, whose chains reference 1..3 transitively
    assert mgr.all_steps() == [1, 2, 3, 4, 5]
    restored, _, got, skipped = mgr.restore_latest(like=st)
    assert got == 5 and not skipped
    assert _trees_equal(restored, st)
    # two consecutive fulls -> nothing references the old chain
    mgr2 = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                             full_every=1, keep=2)
    mgr2.save(6, st)
    mgr2.save(7, st)
    assert mgr2.all_steps() == [6, 7]


def test_zero_dirty_save_writes_no_shard_payload(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = {"w": jax.random.normal(KEY, (4096,))}
    mgr.save(1, st)
    s2 = mgr.save(2, st)                 # identical state
    assert s2.kind == "delta" and s2.dirty_blocks == 0
    files = os.listdir(tmp_path / "step_00000002")
    assert not any(f.startswith("w.s") for f in files)
    restored, _ = mgr.restore(step=2, like=st)
    assert _trees_equal(restored, st)


def test_delta_survives_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = _state()
    mgr.save(1, st, blocking=False)
    st = _state(bump_block=12, base=st)
    s2 = mgr.save(2, st, blocking=False)
    mgr.wait()
    assert s2.kind == "delta"
    restored, _, got, _ = mgr.restore_latest(like=st)
    assert got == 2 and _trees_equal(restored, st)


def test_small_and_integer_leaves_always_full(tmp_path):
    """Leaves under the delta floor and non-float leaves ride along full
    (and stay bit-exact) even in delta mode."""
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = {"big": jax.random.normal(KEY, (4096,)),
          "small": jnp.linspace(-1.0, 1.0, 64),
          "ints": jnp.arange(5000, dtype=jnp.int32)}
    mgr.save(1, st)
    ints = np.asarray(st["ints"]).copy()
    ints[100] += 1                       # one dirty block of twenty
    st2 = {**st, "ints": jnp.asarray(ints)}
    s2 = mgr.save(2, st2)
    assert s2.kind == "delta"
    man = _manifest(tmp_path, 2)
    assert "delta" not in man["arrays"]["small"]["shards"][0]
    assert man["arrays"]["ints"]["shards"][0]["delta"]["local"] == [0]
    restored, _ = mgr.restore(step=2, like=st2)
    assert _trees_equal(restored, st2)


def test_delta_block_must_align_with_codec_block(tmp_path):
    with pytest.raises(ValueError, match="multiple"):
        CheckpointManager(str(tmp_path), delta=True, delta_block=100)


def test_regenerated_parent_step_invalidates_stale_chain(tmp_path):
    """Walk-back + resume can REGENERATE a parent step number with
    different content (new training trajectory).  A stale delta left
    behind by the walk-back must not silently resolve against it — every
    file's CRC would pass while the assembled state mixes generations.
    Lineage ids pin each delta to the exact save it referenced."""
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100, keep=10)
    st1 = _state()
    mgr.save(1, st1)                     # full
    st2 = _state(bump_block=2, base=st1)
    mgr.save(2, st2)                     # delta <- 1
    st3 = _state(bump_block=9, base=st2)
    mgr.save(3, st3)                     # delta <- 1, 2
    # corrupt step 2 -> walk-back lands on step 1 (stale step 3 remains)
    f2 = next(f for f in os.listdir(tmp_path / "step_00000002")
              if f.startswith("w.s"))
    p = tmp_path / "step_00000002" / f2
    raw = bytearray(p.read_bytes())
    raw[-1] ^= 0xFF
    p.write_bytes(bytes(raw))
    _, _, got, _ = mgr.restore_latest(like=st1)
    assert got == 1
    # resume: a NEW step 2 (full — post-restore) replaces the corrupt one
    st2b = _state(bump_block=5, base=st1)
    assert mgr.save(2, st2b).kind == "full"
    # stale step 3 still references the OLD step 2's lineage: it must be
    # refused, not silently assembled from the regenerated step 2
    restored, _, got, skipped = mgr.restore_latest(like=st1)
    assert got == 2
    assert [s for s, _ in skipped] == [3]
    assert "regenerated" in skipped[0][1]
    assert _trees_equal(restored, st2b)


def test_close_releases_uncommitted_staging_registration(tmp_path):
    """A non-committing host's staging dir stays protected while its
    manager lives, but must become sweepable after close() — otherwise an
    abandoned multi-host step leaks for the life of the process."""
    mgr = CheckpointManager(str(tmp_path), host_id=1, num_hosts=2)
    st = _state()
    mgr.save(1, st)                      # host 1 never commits (no ack_h0)
    staging = tmp_path / f"step_00000001.tmp.{os.getpid()}"
    assert staging.exists()
    CheckpointManager(str(tmp_path))     # sweep skips: still registered
    assert staging.exists()
    mgr.close()
    CheckpointManager(str(tmp_path))     # now stale: swept
    assert not staging.exists()


def test_restore_with_inflight_async_save_keeps_next_save_full(tmp_path):
    """restore() must join an in-flight async writer BEFORE resetting the
    delta base — otherwise the writer's completion repopulates the base
    after the reset and the post-rollback save silently becomes a delta
    referencing pre-rollback steps."""
    mgr = CheckpointManager(str(tmp_path), delta=True, delta_block=256,
                            full_every=100)
    st = _state()
    mgr.save(1, st)
    st2 = _state(bump_block=4, base=st)
    mgr.save(2, st2, blocking=False)     # writer in flight
    restored, _ = mgr.restore(step=1, like=st)
    assert mgr._writer is None           # joined, not raced
    assert _trees_equal(restored, st)
    assert mgr.save(3, _state(bump_block=1, base=st)).kind == "full"


def test_policy_amortizes_delta_and_full_costs():
    from repro.core.policy import CheckpointPolicy
    p = CheckpointPolicy(mode="young_daly", ema=0.5)
    p.observe_checkpoint(8.0, kind="full")
    for _ in range(7):
        p.observe_checkpoint(1.0, kind="delta")
    # count-weighted mean: (8*1 + 1*7) / 8 — the amortized per-save C,
    # not an EMA whipsawing between 8 and 1
    assert p.ckpt_cost_s == pytest.approx((8.0 + 7.0) / 8.0)
