"""Interruption detection: UDP heartbeats + termination signals."""
import os
import signal
import time

import pytest

from repro.core import (Dependability, DependabilityConfig, HeartbeatEmitter,
                        HeartbeatMonitor, TerminationSignal)


def test_nonzero_host_requires_monitor_addr(tmp_path):
    """No silent fallback to the discard port: hosts without a monitor must
    be given an explicit address or fail loudly at start()."""
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), heartbeat=True,
        signal_detection=False), host_id=1, num_hosts=2)
    with pytest.raises(ValueError, match="monitor_addr"):
        dep.start()


def test_nonzero_host_emits_to_configured_monitor(tmp_path):
    """A non-zero host with monitor_addr set beats the configured monitor."""
    mon = HeartbeatMonitor(num_hosts=2, period=0.03).start()
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), heartbeat=True,
        monitor_addr=tuple(mon.addr), heartbeat_period=0.03,
        signal_detection=False), host_id=1, num_hosts=2)
    dep.start()
    assert dep.monitor is None and dep.emitter is not None
    # last_seen is pre-seeded at start() (into the future, by the startup
    # grace); wait for a REAL beat to overwrite the seed
    seeded = mon.last_seen[1]
    deadline = time.time() + 3
    while mon.last_seen[1] == seeded and time.time() < deadline:
        time.sleep(0.02)
    assert mon.last_seen[1] != seeded     # a datagram actually arrived
    # host 0 intentionally has no emitter here, so only assert on host 1
    # (host 0 will trip its seeded timeout eventually — that's correct)
    assert 1 not in mon.failed_hosts()
    dep.stop()
    mon.stop()


def test_heartbeat_detects_failstop():
    failures = []
    mon = HeartbeatMonitor(num_hosts=3, period=0.03, timeout_factor=4.0,
                           on_failure=failures.append).start()
    ems = [HeartbeatEmitter(i, mon.addr, 0.03).start() for i in range(3)]
    time.sleep(0.3)
    assert mon.alive_hosts() == [0, 1, 2]
    assert not mon.any_failure()
    ems[1].pause()                       # fail-stop: beats just stop
    deadline = time.time() + 3
    while not mon.any_failure() and time.time() < deadline:
        time.sleep(0.02)
    assert mon.failed_hosts() == [1]
    assert failures == [1]
    assert sorted(mon.alive_hosts()) == [0, 2]
    for e in ems:
        e.stop()
    mon.stop()


def test_heartbeat_rejoin_clears_failure():
    mon = HeartbeatMonitor(num_hosts=1, period=0.03, timeout_factor=3.0
                           ).start()
    em = HeartbeatEmitter(0, mon.addr, 0.03).start()
    time.sleep(0.2)
    em.pause()
    deadline = time.time() + 3
    while not mon.any_failure() and time.time() < deadline:
        time.sleep(0.02)
    assert mon.any_failure()
    em.resume()                          # failover / rejoin
    deadline = time.time() + 3
    while mon.any_failure() and time.time() < deadline:
        time.sleep(0.02)
    assert not mon.any_failure()
    em.stop()
    mon.stop()


def test_silent_from_birth_host_is_declared_failed():
    """A host that NEVER sends a beat must still trip the timeout: start()
    seeds last_seen for all num_hosts (it used to only be populated on
    receipt, so a dead-on-arrival host was never declared failed)."""
    failures = []
    mon = HeartbeatMonitor(num_hosts=2, period=0.03, timeout_factor=4.0,
                           on_failure=failures.append).start()
    em0 = HeartbeatEmitter(0, mon.addr, 0.03).start()   # host 1: no emitter
    deadline = time.time() + 3
    while not mon.any_failure() and time.time() < deadline:
        time.sleep(0.02)
    assert mon.failed_hosts() == [1]
    assert failures == [1]
    assert 0 in mon.alive_hosts()
    em0.stop()
    mon.stop()


def test_acknowledge_excludes_until_rejoin():
    """acknowledge() stops counting a handled failure; the host rejoining
    (beating again) fires on_rejoin and resumes monitoring."""
    failures, rejoins = [], []
    mon = HeartbeatMonitor(num_hosts=2, period=0.03, timeout_factor=4.0,
                           on_failure=failures.append,
                           on_rejoin=rejoins.append).start()
    ems = [HeartbeatEmitter(i, mon.addr, 0.03).start() for i in range(2)]
    time.sleep(0.2)
    ems[1].pause()
    deadline = time.time() + 3
    while not mon.any_failure() and time.time() < deadline:
        time.sleep(0.02)
    assert failures == [1]
    mon.acknowledge(1)                    # recovery layer handled it
    assert not mon.any_failure()
    assert mon.alive_hosts() == [0]       # excluded host is not alive
    time.sleep(0.3)                       # excluded: must NOT re-fail
    assert failures == [1] and not mon.any_failure()
    ems[1].resume()
    deadline = time.time() + 3
    while not rejoins and time.time() < deadline:
        time.sleep(0.02)
    assert rejoins == [1]
    assert 1 in mon.alive_hosts()         # monitored again after rejoin
    for e in ems:
        e.stop()
    mon.stop()


def test_asymmetric_partition_latches_and_orders_rejoin():
    """A network partition is asymmetric: host B keeps emitting (it
    believes itself connected) but its datagrams never reach the monitor —
    A sees B dead while B sees A alive.  The monitor must (1) declare B
    failed, (2) keep B excluded after acknowledge even when a STALE
    in-flight datagram from before the partition finally lands (split-brain
    guard: a beat at or below the last accepted (inc, seq) is not a
    rejoin), and (3) rejoin B through ordinary delivery once the partition
    heals, because B's seq kept advancing behind the cut."""
    import json
    import socket

    failures, rejoins = [], []
    mon = HeartbeatMonitor(num_hosts=2, period=0.03, timeout_factor=4.0,
                           on_failure=failures.append,
                           on_rejoin=rejoins.append).start()
    ems = [HeartbeatEmitter(i, mon.addr, 0.03).start() for i in range(2)]
    time.sleep(0.25)

    # partition: drop B's datagrams in the "network" — B's emitter keeps
    # running and its seq keeps advancing (unlike pause(), which models
    # the process dying)
    ems[1].send_filter = lambda payload: False
    deadline = time.time() + 3
    while not mon.any_failure() and time.time() < deadline:
        time.sleep(0.02)
    assert mon.failed_hosts() == [1] and failures == [1]
    mon.acknowledge(1)                    # recovery layer handled it
    assert 1 in mon.excluded

    # a pre-partition datagram finally delivered: (inc, seq) at/below the
    # last accepted beat must NOT read as a rejoin
    inc, seq = mon._last_beat[1]
    sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
    sock.sendto(json.dumps({"host": 1, "seq": seq, "inc": inc,
                            "t": time.time()}).encode(), tuple(mon.addr))
    sock.close()
    time.sleep(0.25)
    assert rejoins == [] and 1 in mon.excluded
    assert 1 not in mon.alive_hosts()

    # heal: B's live beats carry a seq larger than anything accepted
    # before the cut — ordinary delivery is the rejoin
    ems[1].send_filter = None
    deadline = time.time() + 3
    while not rejoins and time.time() < deadline:
        time.sleep(0.02)
    assert rejoins == [1]
    assert 1 in mon.alive_hosts() and failures == [1]
    for e in ems:
        e.stop()
    mon.stop()


def test_termination_signal_latch():
    ts = TerminationSignal(signals=(signal.SIGUSR1,)).install()
    try:
        assert not ts.triggered()
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert ts.triggered()
        assert ts.received == signal.SIGUSR1
        ts.reset()
        assert not ts.triggered()
    finally:
        ts.uninstall()


def test_signal_triggers_final_checkpoint(tmp_path):
    """Preemption flow: SIGUSR1 mid-training -> final save + clean exit."""
    import jax

    from repro.core import Dependability, DependabilityConfig, run_bsp
    from repro.data import make_pipeline
    from repro.models import get_config
    from repro.train import init_state, make_train_step

    cfg = get_config("gemma-7b", tiny=True)
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="every_n", every_n=1000,
        signal_detection=True)).start()
    data = make_pipeline(cfg, 16, 2)
    dep.register_local_state(data)
    state = init_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))

    sent = {"done": False}

    def on_metrics(s, rec):
        if s == 3 and not sent["done"]:
            sent["done"] = True
            os.kill(os.getpid(), signal.SIGUSR1)

    state, status, hist = run_bsp(dep, step, state, data, 100,
                                  on_metrics=on_metrics)
    assert status == "interrupted"
    assert dep.interruption_cause().startswith("signal:")
    assert dep.manager.latest_step() == 3      # final checkpoint landed
    restored, local = dep.manager.restore(like=state)
    assert local["step"] == 3                  # local state cursor too
    dep.stop()
