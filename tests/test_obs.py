"""Dependability telemetry layer (docs/observability.md): the event bus,
the metrics registry (numpy as the percentile oracle), failure timelines
with MTTR/MTBF/availability, live Young/Daly adaptation, and the
record-and-replay loop (recorded JSONL -> Scenario -> ControlPlaneSim)."""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.obs import (DEFAULT_CAPACITY, Event, EventBus, MetricsRegistry,
                       Observability, Timeline, load_jsonl, to_chrome_trace,
                       to_scenario)

# ---------------------------------------------------------------------------
# event bus
# ---------------------------------------------------------------------------


def test_bus_emit_stamps_and_filters():
    bus = EventBus()
    e1 = bus.emit("heartbeat", "failure", host=3)
    e2 = bus.emit("checkpoint", "save", step=10)
    assert e1.seq == 0 and e2.seq == 1
    assert e2.t_mono >= e1.t_mono and e1.t_wall > 0
    assert [e.kind for e in bus.events()] == ["failure", "save"]
    assert [e.data["host"] for e in bus.events(subsystem="heartbeat")] == [3]
    assert bus.events(kind="save")[0].data == {"step": 10}
    assert bus.events(subsystem="serve") == []
    assert len(bus) == 2 and bus.total_emitted == 2


def test_bus_ring_is_bounded_and_counts_drops():
    bus = EventBus(capacity=5)
    for i in range(12):
        bus.emit("s", "k", i=i)
    assert len(bus) == 5
    assert bus.dropped == 7
    assert bus.total_emitted == 12
    assert [e.data["i"] for e in bus.events()] == [7, 8, 9, 10, 11]
    assert EventBus().capacity == DEFAULT_CAPACITY
    with pytest.raises(ValueError):
        EventBus(capacity=0)


def test_bus_emit_rejects_reserved_payload_keys():
    """A payload key named like an Event field would silently shadow it
    in the flattened JSONL record (and TypeError on the kwarg path) —
    the bus refuses it up front."""
    bus = EventBus()
    # kind/subsystem hit emit's own parameters: loud TypeError from Python
    with pytest.raises(TypeError):
        bus.emit("checkpoint", "save", kind="full")
    # seq/t_mono/t_wall would pass through silently — the guard refuses
    with pytest.raises(ValueError, match="seq"):
        bus.emit("s", "k", seq=7, t_mono=0.0)
    assert len(bus) == 0 and bus.total_emitted == 0
    bus.emit("checkpoint", "save", save_kind="full")      # the renamed form
    assert bus.events()[0].data == {"save_kind": "full"}


def test_run_with_recovery_emits_interrupted_and_resume(tmp_path):
    """Fail-stop through the facade with telemetry attached: the recovery
    loop must put train/interrupted and train/resume on the bus (the
    interrupted emit once collided with the bus's own kind kwarg)."""
    import jax.numpy as jnp
    from repro.core.api import Dependability, DependabilityConfig
    from repro.core.coordinator import run_with_recovery
    from repro.core.failures import FaultInjector

    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path / "ckpt"),
        policy_mode="every_n", every_n=2, signal_detection=False))
    obs = Observability()
    dep.attach_obs(obs)
    dep.start()
    state = {"step": jnp.array(0), "w": jnp.ones((4,))}
    dep.register_global_state(state)

    class Data:
        def next_batch(self):
            return jnp.ones((4,))

    def train_step(state, batch):
        w = state["w"] + 0.01
        return ({"step": state["step"] + 1, "w": w},
                {"loss": float(jnp.sum(w))})

    inj = FaultInjector(obs=obs)
    inj.schedule_failstop(4)
    state, rep = run_with_recovery(dep, train_step, state, Data(), 8,
                                   fault_injector=inj)
    assert rep["status"] == "done" and rep["restarts"] == 1
    kinds = {(e.subsystem, e.kind) for e in obs.events()}
    assert ("train", "interrupted") in kinds
    assert ("train", "resume") in kinds
    ints = obs.events(subsystem="train", kind="interrupted")
    assert ints[0].data["failure_kind"] == "fail-stop"
    assert obs.registry.histogram("train.rollback_depth").count == 1
    dep.stop()


def test_bus_concurrent_emitters_lose_nothing():
    """N threads hammer one bus while a subscriber (running on the
    emitting threads) collects: every event is delivered exactly once and
    sequence numbers are unique."""
    bus = EventBus(capacity=100_000)
    got, got_lock = [], threading.Lock()

    def on_event(ev):
        with got_lock:
            got.append(ev)

    bus.subscribe(on_event)
    threads_n, per_thread = 8, 500

    def worker(tid):
        for i in range(per_thread):
            bus.emit("t", "tick", tid=tid, i=i)

    ts = [threading.Thread(target=worker, args=(t,)) for t in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = threads_n * per_thread
    assert bus.total_emitted == total and len(bus) == total
    assert len(got) == total
    seqs = [e.seq for e in bus.events()]
    assert sorted(seqs) == list(range(total))      # unique, gap-free
    # every (tid, i) pair delivered to the subscriber exactly once
    pairs = {(e.data["tid"], e.data["i"]) for e in got}
    assert len(pairs) == total


def test_bus_subscriber_may_inspect_bus_and_unsubscribe():
    bus = EventBus()
    seen = []

    def hook(ev):
        # callbacks run outside the lock: reading back must not deadlock
        seen.append((ev.kind, len(bus.events())))

    bus.subscribe(hook)
    bus.emit("s", "a")
    bus.unsubscribe(hook)
    bus.emit("s", "b")
    assert seen == [("a", 1)]


def test_bus_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "tele" / "events.jsonl")
    bus = EventBus()
    bus.attach_jsonl(path)                     # creates the parent dir
    bus.emit("heartbeat", "failure", host=2, detection_latency_s=0.21)
    bus.emit("chaos", "kill_hosts", at=6.0, until=None, hosts=[2, 3])
    bus.close()
    back = load_jsonl(path)
    assert [e.to_dict() for e in back] == [e.to_dict()
                                           for e in bus.events()]
    assert back[1].data["hosts"] == [2, 3] and back[1].data["until"] is None
    # re-attaching appends (the log survives a restart)
    bus.attach_jsonl(path)
    bus.emit("s", "more")
    bus.close()
    assert len(load_jsonl(path)) == 3


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=2.0, sigma=1.5, size=1500).tolist()
    reg = MetricsRegistry()
    h = reg.histogram("serve.latency_ms")
    for x in xs:
        h.observe(x)
    for q in (0.0, 25.0, 50.0, 90.0, 99.0, 100.0):
        assert h.percentile(q) == pytest.approx(np.percentile(xs, q),
                                                rel=1e-12)
    assert h.p50 == pytest.approx(np.percentile(xs, 50))
    assert h.count == 1500 and h.sum == pytest.approx(sum(xs))
    with pytest.raises(ValueError):
        h.percentile(101.0)


def test_histogram_window_bounds_percentiles_but_not_count():
    reg = MetricsRegistry()
    h = reg.histogram("x", window=64)
    xs = list(range(1000))
    for x in xs:
        h.observe(float(x))
    # percentiles over the newest 64 samples only; count/sum/min/max over
    # the full stream
    assert h.percentile(50) == pytest.approx(np.percentile(xs[-64:], 50))
    snap = h.snapshot()
    assert snap["count"] == 1000 and snap["min"] == 0.0
    assert snap["max"] == 999.0
    assert snap["mean"] == pytest.approx(np.mean(xs))


def test_counter_gauge_semantics():
    reg = MetricsRegistry()
    c = reg.counter("sdc.detected", tier="abft")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("serve.queue_depth")
    g.set(7)
    g.inc()
    g.dec(2)
    assert g.value == 6


def test_registry_identity_labels_and_type_conflicts():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.counter("a", host=1) is not reg.counter("a", host=2)
    assert reg.histogram("h") is reg.histogram("h")
    with pytest.raises(TypeError):
        reg.gauge("a")                        # "a" is already a Counter
    assert len(reg.instruments()) == 4


def test_span_times_into_histogram():
    reg = MetricsRegistry()
    with reg.span("checkpoint.restore_ms") as sp:
        time.sleep(0.01)
    assert sp.seconds >= 0.01
    h = reg.histogram("checkpoint.restore_ms")
    assert h.count == 1 and h.p50 == pytest.approx(sp.seconds * 1e3)


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("serve.tokens").inc(42)
    reg.gauge("elastic.dp_width").set(4)
    h = reg.histogram("train.step_ms", host=0)
    h.observe(10.0)
    h.observe(20.0)
    text = reg.to_prometheus()
    assert "# TYPE serve_tokens counter" in text
    assert "serve_tokens 42" in text
    assert "# TYPE elastic_dp_width gauge" in text
    assert "elastic_dp_width 4" in text
    assert "# TYPE train_step_ms summary" in text
    assert 'train_step_ms{host="0",quantile="0.5"} 15' in text
    assert 'train_step_ms_count{host="0"} 2' in text
    assert 'train_step_ms_sum{host="0"} 30' in text
    assert "train.step_ms" not in text        # dots sanitized in names


def test_registry_snapshot_and_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    reg.histogram("h").observe(1.0)
    snap = reg.snapshot()
    assert snap["c"] == 2 and snap["h"]["count"] == 1
    path = str(tmp_path / "metrics.json")
    reg.to_json(path)
    with open(path) as f:
        assert json.load(f) == json.loads(reg.to_json())


# ---------------------------------------------------------------------------
# failure timelines
# ---------------------------------------------------------------------------


def _ev(t, subsystem, kind, **data):
    return Event(seq=int(t * 1000), t_mono=t, t_wall=1e9 + t,
                 subsystem=subsystem, kind=kind, data=data)


def test_timeline_assembles_incidents_and_merges_detections():
    events = [
        _ev(0.0, "train", "step", step=0),
        _ev(1.0, "heartbeat", "failure", host=2),          # opens
        _ev(1.1, "sdc", "corruption", step=6),             # merges
        _ev(1.2, "elastic", "shrink", hosts=[2]),          # phase
        _ev(1.5, "checkpoint", "restore", step=4),         # phase
        _ev(2.0, "elastic", "resume", step=4),             # closes
        _ev(5.0, "serve", "replica_failed", replica=1),    # second incident
        _ev(5.5, "serve", "standby_activated", replica=4),
        _ev(6.0, "serve", "retry_first_token", rid=9),
        _ev(10.0, "train", "step", step=20),
    ]
    tl = Timeline.from_events(events)
    assert len(tl.incidents) == 2 and len(tl.closed) == 2
    first, second = tl.incidents
    assert first.cause == "heartbeat.failure"
    assert len(first.detections) == 2                      # merged, not split
    assert first.duration == pytest.approx(1.0)
    assert [k for _, k in first.phase_offsets_ms()] == [
        "sdc.corruption", "elastic.shrink", "checkpoint.restore",
        "resume:elastic.resume"]
    assert second.duration == pytest.approx(1.0)
    assert tl.mttr() == pytest.approx(1.0)
    assert tl.mtbf() == pytest.approx(4.0)                 # starts 1.0, 5.0
    assert tl.downtime() == pytest.approx(2.0)
    assert tl.availability() == pytest.approx(1.0 - 2.0 / 10.0)
    s = tl.summary()
    assert s["incidents"] == 2 and s["closed"] == 2
    assert s["causes"] == ["heartbeat.failure", "serve.replica_failed"]


def test_timeline_open_incident_counts_as_down_until_log_end():
    events = [
        _ev(0.0, "train", "step", step=0),
        _ev(4.0, "heartbeat", "failure", host=1),
        _ev(10.0, "train", "step", step=9),                # never resumed
    ]
    tl = Timeline.from_events(events)
    assert len(tl.closed) == 0 and tl.mttr() is None
    assert tl.mtbf() is None                               # one incident
    assert tl.downtime() == pytest.approx(6.0)
    assert tl.availability() == pytest.approx(0.4)
    inc = tl.incidents[0]
    assert inc.duration is None and inc.to_dict()["duration_s"] is None


def test_timeline_resume_without_incident_is_ignored():
    tl = Timeline.from_events([_ev(1.0, "train", "resume", step=3),
                               _ev(2.0, "train", "step", step=4)])
    assert tl.incidents == [] and tl.availability() == 1.0
    assert Timeline.from_events([]).availability() == 1.0


# ---------------------------------------------------------------------------
# exporters: chrome trace + record-and-replay
# ---------------------------------------------------------------------------


def test_chrome_trace_has_tracks_and_incident_bars():
    events = [
        _ev(1.0, "heartbeat", "failure", host=2),
        _ev(1.4, "checkpoint", "restore", step=4),
        _ev(2.0, "elastic", "resume", step=4),
    ]
    trace = to_chrome_trace(events)
    names = [t.get("name") for t in trace["traceEvents"]]
    assert "heartbeat.failure" in names and "elastic.resume" in names
    bars = [t for t in trace["traceEvents"] if t["ph"] == "X"]
    assert len(bars) == 1
    assert bars[0]["name"] == "incident:heartbeat.failure"
    assert bars[0]["dur"] == pytest.approx(1.0e6)          # us
    assert trace["otherData"]["summary"]["incidents"] == 1


def test_to_scenario_declarative_round_trip_is_lossless():
    """The chaos driver records its compiled scenario on the bus; the
    converter reconstructs it bit-identically — name, clock, seed, and
    every event including window kinds."""
    from repro.chaos import Scenario, TrainScenarioDriver
    sc = (Scenario("compound", clock="step", seed=42)
          .kill_hosts([2, 3], at=6)
          .sdc_storm(rate=0.3, window=(4, 10))
          .traffic_spike(mult=4, window=(3, 12))
          .rejoin(2, at=16)
          .rejoin(3, at=16))

    class _E:
        send_filter = None

        def pause(self):
            pass

        def resume(self):
            pass

    obs = Observability()
    TrainScenarioDriver(sc, emitters={h: _E() for h in range(4)},
                        leaf_names=["params.w"], settle_seconds=0, obs=obs)
    back = obs.to_scenario()
    assert back.to_dict() == sc.to_dict()
    assert back.seed == 42 and back.clock == "step"
    assert back.name == "compound"
    # the name override still applies
    assert obs.to_scenario(name="renamed").name == "renamed"


def test_to_scenario_declarative_survives_jsonl(tmp_path):
    """Record -> JSONL on disk -> load -> Scenario: the full durable loop."""
    from repro.chaos import Scenario, TrainScenarioDriver
    sc = Scenario("s", seed=9).kill_hosts([1], at=3).rejoin(1, at=8)

    class _E:
        send_filter = None

        def pause(self):
            pass

        def resume(self):
            pass

    path = str(tmp_path / "events.jsonl")
    obs = Observability(jsonl_path=path)
    TrainScenarioDriver(sc, emitters={0: _E(), 1: _E()},
                        settle_seconds=0, obs=obs)
    obs.close()
    back = to_scenario(load_jsonl(path))
    assert back.to_dict() == sc.to_dict()


def test_to_scenario_derived_from_detections_replays_through_sim():
    """No chaos events on the bus (a "production" log): the converter
    derives a time-clock scenario from raw heartbeat detections, and the
    result drives the control-plane simulator."""
    from repro.chaos import ControlPlaneSim
    events = [
        _ev(0.0, "train", "step", step=0),
        _ev(0.5, "heartbeat", "failure", host=1, detection_latency_s=0.2),
        _ev(0.6, "heartbeat", "failure", host=1),          # duplicate: once
        _ev(2.0, "heartbeat", "rejoin", host=1),
        _ev(2.1, "injector", "bitflip", step=5, leaf="params.w", bit=3),
        _ev(2.6, "injector", "bitflip", step=6, leaf="params.w", bit=9),
    ]
    sc = to_scenario(events)
    assert sc.clock == "time" and sc.name == "derived-replay"
    kills = sc.point_events("kill_hosts")
    assert len(kills) == 1 and kills[0].args["hosts"] == [1]
    assert kills[0].at == pytest.approx(0.5)
    assert sc.point_events("rejoin")[0].at == pytest.approx(2.0)
    storms = sc.window_events("sdc_storm")
    assert len(storms) == 1
    assert storms[0].args["leaves"] == ["params.w"]
    assert storms[0].at == pytest.approx(2.1)
    rep = ControlPlaneSim(4, period=0.1).run(sc)
    assert {d["host"] for d in rep.detections} == {1}
    assert sorted(h for _, hs in rep.grow_events for h in hs) == [1]


# ---------------------------------------------------------------------------
# Observability bundle
# ---------------------------------------------------------------------------


def test_observability_snapshot_and_dump(tmp_path):
    obs = Observability(capacity=100)
    obs.emit("heartbeat", "failure", host=2)
    obs.emit("elastic", "resume", step=4)
    obs.registry.counter("heartbeat.failures").inc()
    snap = obs.snapshot()
    assert snap["events"] == {"retained": 2, "emitted": 2, "dropped": 0}
    assert snap["timeline"]["incidents"] == 1
    assert snap["metrics"]["heartbeat.failures"] == 1
    out = str(tmp_path / "tele")
    paths = obs.dump(out)
    # no sink was attached: dump back-fills the retained ring
    assert len(load_jsonl(paths["events"])) == 2
    with open(paths["trace"]) as f:
        assert json.load(f)["otherData"]["summary"]["closed"] == 1
    with open(paths["metrics_json"]) as f:
        assert json.load(f)["heartbeat.failures"] == 1
    with open(paths["metrics_prom"]) as f:
        assert "heartbeat_failures 1" in f.read()
    # a second dump with the sink now attached reuses the live log
    obs.emit("s", "more")
    assert obs.dump(out)["events"] == paths["events"]
    assert len(load_jsonl(paths["events"])) == 3
    obs.close()


# ---------------------------------------------------------------------------
# live integration: heartbeat latency, Young/Daly feedback, serve back-compat
# ---------------------------------------------------------------------------


def test_heartbeat_monitor_records_detection_latency():
    from repro.core import HeartbeatEmitter, HeartbeatMonitor
    obs = Observability()
    period = 0.05
    detected = threading.Event()
    mon = HeartbeatMonitor(num_hosts=2, period=period, timeout_factor=4.0,
                           on_failure=lambda h: detected.set(),
                           obs=obs).start()
    ems = [HeartbeatEmitter(i, mon.addr, period).start() for i in range(2)]
    time.sleep(8 * period)                    # establish liveness
    ems[1].pause()
    assert detected.wait(5.0)
    lat = mon.detection_latency[1]
    # declared after ~timeout (4 periods) from the last accepted beat
    assert 0.0 < lat < 2.0
    evs = obs.events(subsystem="heartbeat", kind="failure")
    assert evs and evs[0].data["host"] == 1
    assert evs[0].data["detection_latency_s"] == pytest.approx(lat)
    h = obs.registry.histogram("heartbeat.detection_latency_ms", host=1)
    assert h.count == 1 and h.p50 == pytest.approx(lat * 1e3)
    assert obs.registry.counter("heartbeat.failures").value == 1
    for e in ems:
        e.stop()
    mon.stop()


def test_policy_observe_recovery_adapts_young_daly_terms():
    from repro.core.policy import CheckpointPolicy, SystemModel
    pol = CheckpointPolicy(mode="young_daly",
                           system=SystemModel(restart_seconds=120.0,
                                              downtime_seconds=60.0),
                           ema=0.7)
    pol.observe_recovery(restart_s=2.0, downtime_s=0.5)
    assert pol.system.restart_seconds == pytest.approx(0.7 * 120 + 0.3 * 2)
    assert pol.system.downtime_seconds == pytest.approx(0.7 * 60 + 0.3 * 0.5)
    before = pol.system.restart_seconds
    pol.observe_recovery(downtime_s=0.5)      # partial update: R untouched
    assert pol.system.restart_seconds == before
    # repeated measurements converge on the measured value
    for _ in range(60):
        pol.observe_recovery(restart_s=2.0, downtime_s=0.5)
    assert pol.system.restart_seconds == pytest.approx(2.0, rel=1e-3)
    assert pol.system.downtime_seconds == pytest.approx(0.5, rel=1e-3)


def test_serve_engine_events_backcompat_via_bus():
    """``ServeEngine.events`` is now a view over the shared bus: same
    ``{"t", "step", "event", ...}`` dicts as the old list, same data, and
    the same handle also feeds the engine's latency histograms."""
    import jax
    from repro.core import FaultInjector
    from repro.models import get_config, init_params
    from repro.serve import ServeEngine
    cfg = get_config("granite-3-8b", tiny=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    obs = Observability()
    inj = FaultInjector()
    inj.schedule_replica_kill(2, replica_id=1)
    eng = ServeEngine(cfg, params, num_replicas=2, slots_per_replica=2,
                      max_len=12, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      fault_injector=inj, obs=obs)
    assert eng.obs is obs                     # shared, not engine-private
    rids = [eng.submit([1, 2, 3, 4], 4) for _ in range(3)]
    results = eng.run()
    assert len(results) == len(rids)
    evs = eng.events
    assert evs, "the failover must have recorded lifecycle events"
    assert all(set(e) >= {"t", "step", "event"} for e in evs)
    assert any(e["event"] == "replica_failed" for e in evs)
    assert [e.kind for e in obs.events(subsystem="serve")] \
        == [e["event"] for e in evs]
    assert obs.registry.counter("serve.replica_failures").value == 1
    assert obs.registry.histogram("serve.latency_ms").count == len(rids)
    assert obs.registry.counter("serve.requests_done").value == len(rids)
    assert obs.registry.counter("serve.tokens").value >= 4
    eng.shutdown()
    # an engine built without a handle still owns one (back-compat)
    eng2 = ServeEngine(cfg, params, num_replicas=1, slots_per_replica=2,
                      max_len=12, fault_tolerant=False)
    assert eng2.obs is not None and eng2.events == []
    eng2.shutdown()


def test_train_driver_history_rides_the_bus():
    """With obs attached the per-step records live on the bus; history()
    still merges newest-per-step, and records that fell off a small ring
    are recovered from the driver's local dict."""
    from repro.chaos import Scenario, TrainScenarioDriver
    obs = Observability(capacity=3)
    d = TrainScenarioDriver(Scenario("s"), settle_seconds=0, obs=obs)
    for step in range(6):
        d.on_metrics(step, {"step": step, "loss": 1.0 - step / 10})
    d.on_metrics(2, {"step": 2, "loss": 0.55})      # replay overwrites
    hist = d.history()
    assert [h["step"] for h in hist] == [0, 1, 2, 3, 4, 5]
    assert hist[2]["loss"] == 0.55
