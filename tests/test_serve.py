"""Serving engine: scheduler/pool invariants, continuous-batching
determinism, and E2E replica failover (docs/serving.md).

The failover contract under test: killing a replica mid-decode loses zero
requests, and the retried requests' greedy token streams are identical to
an uninterrupted run — greedy decode is a pure function of the prompt, so
re-execution on a survivor replays the same stream.
"""
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FaultInjector, HeartbeatMonitor, SimulatedFailure
from repro.models import get_config, init_cache, init_params
from repro.sdc import DecodeSentinel
from repro.serve import (CachePool, NoHealthyReplicasError, PoolExhausted,
                         QueueFull, Scheduler, ServeEngine)
from repro.train import logit_stats, make_decode_step, make_prefill_step

CFG = get_config("granite-3-8b", tiny=True)
KEY = jax.random.PRNGKey(0)
MAX_LEN = 32


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, KEY)


def _prompts(n, lens=(4, 6, 8, 5, 7, 4, 9, 6)):
    return [list(range(5 + i, 5 + i + lens[i % len(lens)]))
            for i in range(n)]


def _reference_streams(params, prompts, gen):
    """B=1 sequential greedy decode per request — the oracle every engine
    configuration must reproduce token for token."""
    prefill = jax.jit(make_prefill_step(CFG))
    decode = jax.jit(make_decode_step(CFG))
    out = []
    for p in prompts:
        toks = jnp.asarray(p, jnp.int32)[None]
        tok, row = prefill(params, {"tokens": toks},
                           init_cache(CFG, 1, MAX_LEN))
        s = [int(tok[0])]
        for _ in range(gen - 1):
            tok, row = decode(params, {"tokens": tok[:, None]}, row)
            s.append(int(tok[0]))
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# scheduler state machine + admission control
# ---------------------------------------------------------------------------

def test_scheduler_admission_control():
    s = Scheduler(max_pending=2)
    s.submit([1], 4)
    s.submit([2], 4)
    with pytest.raises(QueueFull):
        s.submit([3], 4)
    assert s.pending() == 2


def test_scheduler_state_machine_and_requeue():
    s = Scheduler()
    r = s.submit([1, 2], 3)
    with pytest.raises(ValueError):
        s.start_decode(r, 7)             # QUEUED -> DECODE is illegal
    assert s.pop_queued() is r
    s.start_prefill(r, slot=0, replica=0)
    s.start_decode(r, 7)
    assert s.append_token(r, 8) is False
    # replica dies: requeue discards partial output, request back at front
    s.requeue(r)
    assert r.state == "QUEUED" and r.tokens == [] and r.slot is None
    assert s.pop_queued() is r and r.retries == 1
    s.start_prefill(r, 1, 1)
    s.start_decode(r, 7)
    s.append_token(r, 8)
    assert s.append_token(r, 9) is True  # budget reached
    s.finish(r)
    assert s.all_done() and s.results() == {r.rid: [7, 8, 9]}


def test_scheduler_retry_budget_exhausted():
    s = Scheduler(max_retries=1)
    r = s.submit([1], 2)
    for _ in range(2):
        s.pop_queued()
        s.start_prefill(r, 0, 0)
        s.requeue(r)
    assert r.state == "FAILED" and s.failed_rids == [r.rid]
    assert s.all_done() and r.rid not in s.results()


def test_scheduler_requeued_requests_keep_fifo_front():
    s = Scheduler()
    a, b, c = (s.submit([i], 2) for i in range(3))
    s.pop_queued(); s.start_prefill(a, 0, 0)
    s.pop_queued(); s.start_prefill(b, 1, 0)
    # drain in slot order: appendleft b then... router drains [a, b]; the
    # engine requeues in drained order, so b ends up in front of a — both
    # ahead of the never-started c is NOT required; what matters is no
    # request is lost and each retry re-enters the queue exactly once
    s.requeue(a)
    s.requeue(b)
    popped = [s.pop_queued().rid for _ in range(3)]
    assert sorted(popped) == [a.rid, b.rid, c.rid]
    assert popped[-1] == c.rid           # retried requests go first


def test_requeue_clears_first_token_time():
    """A retried request's pre-failure t_first_token was discarded with
    its partial output; keeping the stamp would make BENCH_serve's p50/p99
    understate failover latency.  requeue itself must clear it (every
    drain path goes through requeue, including the FAILED terminal)."""
    s = Scheduler(max_retries=1)
    r = s.submit([1, 2], 4)
    s.pop_queued()
    s.start_prefill(r, 0, 0)
    s.start_decode(r, 7)
    r.t_first_token = 123.0              # engine stamped the first token
    s.requeue(r)
    assert r.t_first_token is None       # retry must restamp
    s.pop_queued()
    s.start_prefill(r, 0, 1)
    r.t_first_token = 456.0
    s.requeue(r)                         # budget exhausted -> FAILED
    assert r.state == "FAILED" and r.t_first_token is None


def test_reap_evicts_finished_requests():
    """DONE/FAILED requests must be evictable or scheduler.requests grows
    without bound under sustained traffic (one leaked Request per served
    stream)."""
    s = Scheduler(max_retries=0)
    done = s.submit([1], 1)
    s.pop_queued(); s.start_prefill(done, 0, 0); s.start_decode(done, 7)
    s.finish(done)
    failed = s.submit([2], 2)
    s.pop_queued(); s.start_prefill(failed, 0, 0)
    s.requeue(failed)                    # max_retries=0 -> FAILED
    flying = s.submit([3], 2)
    s.pop_queued(); s.start_prefill(flying, 1, 0)

    with pytest.raises(ValueError, match="not finished"):
        s.reap(flying.rid)               # in-flight: caller bug
    got = s.reap(done.rid)
    assert got.tokens == [7]
    with pytest.raises(KeyError):
        s.reap(done.rid)                 # double-reap
    reaped = s.reap_finished()           # drains the FAILED one too
    assert [r.rid for r in reaped] == [failed.rid]
    assert set(s.requests) == {flying.rid}   # bounded by in-flight


def test_observability_lists_are_capped():
    from repro.serve.scheduler import OBSERVABILITY_CAP
    s = Scheduler(max_pending=10**9, max_retries=10**9)
    r = s.submit([1], 2)
    s.pop_queued()
    for _ in range(OBSERVABILITY_CAP + 100):
        s.start_prefill(r, 0, 0)
        s.requeue(r)
        s.pop_queued()
    assert len(s.retried_rids) == OBSERVABILITY_CAP


def test_engine_drain_finished_bounds_request_map(params):
    prompts = _prompts(4)
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=False, sentinel=False)
    rids = [eng.submit(p, 4) for p in prompts]
    res = eng.run()
    assert len(eng.scheduler.requests) == len(prompts)
    drained = eng.drain_finished()
    assert drained == res                # same rid -> tokens mapping
    assert eng.scheduler.requests == {}  # record map fully drained
    # a second wave starts from a clean slate
    rid2 = eng.submit(prompts[0], 4)
    res2 = eng.run()
    assert res2 == {rid2: res[rids[0]]}  # greedy stream reproducible
    assert eng.reap(rid2) == res2[rid2]
    assert eng.scheduler.requests == {}
    eng.shutdown()


# ---------------------------------------------------------------------------
# cache pool slot invariants
# ---------------------------------------------------------------------------

def test_cache_pool_slot_accounting():
    pool = CachePool(CFG, num_slots=2, max_len=MAX_LEN)
    s0 = pool.acquire(rid=10)
    s1 = pool.acquire(rid=11)
    assert {s0, s1} == {0, 1} and pool.free_count == 0
    with pytest.raises(PoolExhausted):
        pool.acquire(rid=12)
    pool.release(s0)
    assert pool.free_count == 1 and pool.owner(s1) == 11
    with pytest.raises(ValueError):
        pool.release(s0)                 # double release
    assert pool.acquire(rid=13) == s0    # recycled
    drained = pool.release_all()
    assert sorted(drained) == [11, 13]
    assert pool.free_count == 2 and pool.active_slots == []


def test_cache_pool_release_all_slot_order():
    pool = CachePool(CFG, num_slots=3, max_len=MAX_LEN)
    for rid in (7, 8, 9):
        pool.acquire(rid)
    assert pool.release_all() == [7, 8, 9]   # slot order == admission order


def test_cache_pool_write_row_resets_stale_entries(params):
    """Slot recycling must not leak the previous occupant's cache: a
    recycled slot's pos entries beyond the new prompt must be -1 (empty),
    not the old request's positions."""
    pool = CachePool(CFG, num_slots=2, max_len=MAX_LEN)
    prefill = jax.jit(make_prefill_step(CFG))
    long_row = prefill(params, {"tokens": jnp.arange(20)[None] % 50},
                       init_cache(CFG, 1, MAX_LEN))[1]
    pool.write_row(0, long_row)
    short_row = prefill(params, {"tokens": jnp.arange(4)[None] % 50},
                        init_cache(CFG, 1, MAX_LEN))[1]
    pool.write_row(0, short_row)
    flat = jax.tree_util.tree_flatten_with_path(pool.cache)[0]
    pos_leaves = [v for path, v in flat
                  if getattr(path[-1], "key", "") == "pos"]
    assert pos_leaves, "no pos leaves in cache"
    for leaf in pos_leaves:
        row0 = np.asarray(jax.device_get(leaf))[0]     # slot 0
        assert (row0.reshape(-1, row0.shape[-1])[:, 4:] == -1).all(), \
            "stale cache positions leaked through slot recycling"


# ---------------------------------------------------------------------------
# decode sentinel
# ---------------------------------------------------------------------------

def test_decode_sentinel_nonfinite_and_spike():
    s = DecodeSentinel(spike_factor=4.0, warmup=3)
    assert "non-finite" in s.observe(0, nonfinite=1.0, entropy=1.0)
    for i in range(4):
        assert s.observe(i, 0.0, 1.0) is None
    assert "spike" in s.observe(5, 0.0, 10.0)
    # the EMA did not absorb the spike: a healthy step still passes
    assert s.observe(6, 0.0, 1.1) is None
    assert s.trips == 2


def test_decode_sentinel_absolute_ceiling_trips_during_warmup():
    s = DecodeSentinel(abs_max_entropy=5.0, warmup=100)
    assert s.observe(0, 0.0, 1.0) is None
    assert "ceiling" in s.observe(1, 0.0, 5.5)
    s.reset()
    assert s.entropy_ema is None and s.observed == 0


def test_logit_stats_entropy_and_nonfinite():
    V = CFG.padded_vocab
    uniform = jnp.zeros((1, V), jnp.float32)
    st = logit_stats(CFG, uniform)
    assert abs(float(st["entropy"][0]) - math.log(V)) < 1e-3
    assert float(st["nonfinite"][0]) == 0.0
    bad = uniform.at[0, 3].set(jnp.nan)
    assert float(logit_stats(CFG, bad)["nonfinite"][0]) == 1.0
    # a confident (peaked) distribution has near-zero entropy
    peaked = jnp.full((1, V), -1e9, jnp.float32).at[0, 0].set(0.0)
    assert float(logit_stats(CFG, peaked)["entropy"][0]) < 1e-3


# ---------------------------------------------------------------------------
# fault injector: replica-scoped events
# ---------------------------------------------------------------------------

def test_fault_injector_replica_kill_targets_one_replica():
    inj = FaultInjector()
    inj.schedule_replica_kill(3, replica_id=1)
    inj.check_replica(2, 1)              # before the step: nothing
    inj.check_replica(3, 0)              # wrong replica: nothing
    with pytest.raises(SimulatedFailure) as e:
        inj.check_replica(3, 1)
    assert e.value.kind == "replica-kill" and e.value.host_id == 1
    inj.check_replica(4, 1)              # fires exactly once
    assert inj.replica_kills == [(3, 1)]


def test_fault_injector_kill_lands_past_scheduled_step():
    # the victim may not be dispatched at the exact step — >= semantics
    inj = FaultInjector()
    inj.schedule_replica_kill(3, replica_id=0)
    with pytest.raises(SimulatedFailure):
        inj.check_replica(7, 0)


def test_fault_injector_latency_spike():
    inj = FaultInjector()
    inj.schedule_latency_spike(1, 0.05, replica_id=1)
    t0 = time.perf_counter()
    inj.check_replica(1, 0)              # untargeted replica: no sleep
    assert time.perf_counter() - t0 < 0.04
    t0 = time.perf_counter()
    inj.check_replica(1, 1)
    assert time.perf_counter() - t0 >= 0.05
    t0 = time.perf_counter()
    inj.check_replica(1, 1)              # consumed
    assert time.perf_counter() - t0 < 0.04


# ---------------------------------------------------------------------------
# heartbeat: replica-scoped registration
# ---------------------------------------------------------------------------

def test_monitor_watch_unwatch():
    mon = HeartbeatMonitor(num_hosts=1, period=0.02).start()
    try:
        assert mon.alive_hosts() == [0]
        mon.watch(5)                     # standby activated into the pool
        assert 5 in mon.alive_hosts()
        mon.unwatch(5)                   # decommissioned on purpose
        assert 5 not in mon.alive_hosts()
        assert 5 not in mon.failed_hosts()
    finally:
        mon.stop()


# ---------------------------------------------------------------------------
# engine: continuous batching correctness
# ---------------------------------------------------------------------------

def test_engine_streams_match_single_request_reference(params):
    """5 requests through 3 slots (so admission waits on slot recycling):
    every stream must equal the B=1 sequential oracle."""
    prompts = _prompts(5)
    gen = 6
    ref = _reference_streams(params, prompts, gen)
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=3,
                      max_len=MAX_LEN, fault_tolerant=False)
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    eng.shutdown()
    assert len(res) == len(prompts)
    for rid, r in zip(rids, ref):
        assert res[rid] == r


def test_engine_interleave_determinism_any_arrival_order(params):
    """Same request set, different arrival orders and a mid-flight second
    wave: per-request token streams are identical — the invariant that
    makes failover replay exact."""
    prompts = _prompts(6)
    gen = 5

    def run_order(order, second_wave_at=None):
        eng = ServeEngine(CFG, params, num_replicas=1,
                          slots_per_replica=2, max_len=MAX_LEN,
                          fault_tolerant=False)
        streams = {}
        first = order if second_wave_at is None else order[:3]
        rids = {eng.submit(prompts[i], gen): i for i in first}
        if second_wave_at is not None:
            for _ in range(second_wave_at):
                eng.step()               # decode already in flight...
            for i in order[3:]:
                rids[eng.submit(prompts[i], gen)] = i
        res = eng.run()
        eng.shutdown()
        for rid, i in rids.items():
            streams[i] = res[rid]
        return streams

    a = run_order([0, 1, 2, 3, 4, 5])
    b = run_order([5, 3, 1, 0, 2, 4])
    c = run_order([2, 4, 0, 5, 1, 3], second_wave_at=3)
    assert a == b == c


def test_engine_pool_never_oversubscribed(params):
    """Slot admission invariant, checked at every engine step: at most
    ``slots_per_replica`` owners, each owning exactly one live request."""
    prompts = _prompts(5)
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=False)
    for p in prompts:
        eng.submit(p, 4)
    rep = eng.router.replicas[0]
    while not eng.scheduler.all_done():
        eng.step()
        owners = [rep.pool.owner(s) for s in rep.pool.active_slots]
        assert len(owners) <= 2 and len(set(owners)) == len(owners)
        for rid in owners:
            assert eng.scheduler.requests[rid].state == "DECODE"
    eng.shutdown()
    assert len(eng.results()) == 5


def test_engine_rejects_request_exceeding_cache_bound(params):
    """prompt + generation beyond max_len must be rejected at admission:
    past it the rolling cache wraps and silently corrupts the stream."""
    eng = ServeEngine(CFG, params, slots_per_replica=2, max_len=8,
                      fault_tolerant=False)
    with pytest.raises(ValueError):
        eng.submit(list(range(6)), 4)    # needs 9 positions > 8
    eng.submit(list(range(5)), 4)        # needs exactly 8: admitted
    eng.shutdown()


def test_engine_rejects_encoder_only_and_embedding_models(params):
    enc = get_config("hubert-xlarge", tiny=True)
    with pytest.raises(ValueError):
        ServeEngine(enc, {}, max_len=8)
    vlm = get_config("qwen2-vl-2b", tiny=True)
    with pytest.raises(ValueError):
        ServeEngine(vlm, {}, max_len=8)


# ---------------------------------------------------------------------------
# E2E failover
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_e2e_failover_kill_replica_mid_decode(params):
    """The acceptance-criteria scenario: 2 replicas, kill one mid-decode
    via FaultInjector.schedule_replica_kill -> its requests drain, retry
    on the survivor, token streams identical to an uninterrupted run,
    zero dropped requests."""
    prompts = _prompts(6)
    gen = 8
    ref = _reference_streams(params, prompts, gen)

    inj = FaultInjector()
    inj.schedule_replica_kill(3, replica_id=1)
    # generous timeout: heartbeat detection is not under test here, and a
    # GC/compile pause in a long pytest process must not false-positive
    # the healthy replica
    eng = ServeEngine(CFG, params, num_replicas=2, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      fault_injector=inj)
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    events = [e["event"] for e in eng.events]
    retried = list(eng.scheduler.retried_rids)
    fail_t = next(e["t"] for e in eng.events
                  if e["event"] == "replica_failed")
    restamped = [eng.scheduler.requests[rid].t_first_token
                 for rid in set(retried)]
    eng.shutdown()

    assert inj.replica_kills and inj.replica_kills[0][1] == 1
    assert "replica_failed" in events
    assert retried, "the kill must have drained in-flight requests"
    # requeue cleared the pre-failure stamp; the retry restamped it AFTER
    # the failure — TTFT percentiles now include the failover latency
    assert all(t is not None and t > fail_t for t in restamped)
    assert eng.scheduler.failed_rids == []          # zero dropped
    assert len(res) == len(prompts)                 # zero dropped
    for rid, r in zip(rids, ref):
        assert res[rid] == r, f"retried stream diverged for rid {rid}"


@pytest.mark.slow
def test_e2e_failover_heartbeat_detected(params):
    """Fail-stop the paper's way: the replica's beats just stop (emitter
    pause, no exception anywhere).  The monitor times out, the engine
    drains the replica at the next step boundary, survivors finish
    everything."""
    prompts = _prompts(4)
    gen = 24
    ref = _reference_streams(params, prompts, gen)
    period = 0.05
    eng = ServeEngine(CFG, params, num_replicas=2, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=True,
                      heartbeat_period=period, heartbeat_timeout_factor=6.0)
    rids = [eng.submit(p, gen) for p in prompts]
    victim = eng.router.replicas[1]
    steps = 0
    while not eng.scheduler.all_done():
        eng.step()
        steps += 1
        if steps == 3:
            victim.emitter.pause()       # beats stop; nothing raises
            time.sleep(10 * period)      # let the timeout elapse
    res = eng.results()
    reasons = [e.get("reason") for e in eng.events
               if e["event"] == "replica_failed"]
    eng.shutdown()
    assert "heartbeat-timeout" in reasons, eng.events
    assert not victim.healthy
    assert len(res) == len(prompts)
    for rid, r in zip(rids, ref):
        assert res[rid] == r


@pytest.mark.slow
def test_e2e_sentinel_flags_corrupt_replica(params):
    """Decode-path SDC: scramble one replica's params mid-serve; the
    DecodeSentinel flags the non-finite/garbage logits, the replica is
    excluded, and the retried requests still produce oracle streams."""
    prompts = _prompts(4)
    gen = 10
    ref = _reference_streams(params, prompts, gen)
    eng = ServeEngine(CFG, params, num_replicas=2, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      sentinel=True)
    rids = [eng.submit(p, gen) for p in prompts]
    for _ in range(2):
        eng.step()
    victim = eng.router.replicas[1]
    victim.params = jax.tree.map(lambda x: x * jnp.nan, victim.params)
    res = eng.run()
    reasons = [e.get("reason", "") for e in eng.events
               if e["event"] == "replica_failed"]
    eng.shutdown()
    assert any(r.startswith("sentinel:") for r in reasons), eng.events
    assert len(res) == len(prompts)
    for rid, r in zip(rids, ref):
        assert res[rid] == r


@pytest.mark.slow
def test_e2e_warm_standby_restores_capacity(tmp_path, params):
    """Kill the ONLY replica: a warm standby restored via
    CheckpointManager.restore_latest takes over and finishes every
    request with oracle streams."""
    from repro.core import CheckpointManager
    from repro.serve import make_standby_source

    prompts = _prompts(3)
    gen = 6
    ref = _reference_streams(params, prompts, gen)
    manager = CheckpointManager(str(tmp_path), fsync="none")
    manager.save(0, {"params": params})
    like = jax.eval_shape(lambda: params)

    inj = FaultInjector()
    inj.schedule_replica_kill(2, replica_id=0)
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      fault_injector=inj)
    eng.add_standby(make_standby_source(manager, like))
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    events = [e["event"] for e in eng.events]
    eng.shutdown()
    manager.close()
    assert "standby_activated" in events, eng.events
    assert len(res) == len(prompts)
    for rid, r in zip(rids, ref):
        assert res[rid] == r


def test_all_replicas_dead_no_standby_raises(params):
    inj = FaultInjector()
    inj.schedule_replica_kill(0, replica_id=0)
    eng = ServeEngine(CFG, params, num_replicas=1, slots_per_replica=2,
                      max_len=MAX_LEN, fault_tolerant=True,
                      heartbeat_period=0.05, heartbeat_timeout_factor=40.0,
                      fault_injector=inj)
    eng.submit(_prompts(1)[0], 4)
    with pytest.raises(NoHealthyReplicasError):
        eng.run()
    eng.shutdown()
