"""Slice shard mode + local-scope shard remapping (elastic).

Separate from test_data.py: that module is gated on hypothesis, and these
tests must run everywhere (tier-1 collects them for the failover loop)."""
import numpy as np
import pytest

from repro.data import ShardedPipeline, make_pipeline
from repro.models import get_config

CFG = get_config("granite-3-8b", tiny=True)


def _tok(b):
    return np.asarray(b["tokens"])

def test_slice_mode_global_batch_is_width_independent():
    """The merged global batch must be identical for ANY DP width — the
    invariant the elastic failover loop relies on to keep the loss
    trajectory unchanged across a mesh shrink/grow."""
    ref = ShardedPipeline(CFG, 8, 4, dp_width=1, seed=7)
    for width in (2, 4):
        p = ShardedPipeline(CFG, 8, 4, dp_width=width, seed=7)
        for _ in range(3):
            a, b = ref.next_batch(), p.next_batch()
            for k in a:
                assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k
        ref = ShardedPipeline(CFG, 8, 4, dp_width=1, seed=7)


def test_slice_shards_tile_the_global_batch():
    full = make_pipeline(CFG, 8, 4, seed=1, shard_mode="slice")
    parts = [make_pipeline(CFG, 8, 4, seed=1, host_id=i, num_hosts=2,
                           shard_mode="slice") for i in range(2)]
    fb = _tok(full.peek_batch(0))
    got = np.concatenate([_tok(p.peek_batch(0)) for p in parts], axis=0)
    assert np.array_equal(fb, got)


def test_shard_state_remap_across_widths():
    """Per-shard cursors saved at width 4 restore onto width 2 (shrink)
    and width 1 (full collapse) with the stream continuing exactly."""
    ref = ShardedPipeline(CFG, 8, 4, dp_width=1, seed=0)
    stream = [_tok(ref.next_batch()) for _ in range(8)]

    p4 = ShardedPipeline(CFG, 8, 4, dp_width=4, seed=0)
    for _ in range(3):
        p4.next_batch()
    saved = p4.shard_state_dicts()
    assert len(saved) == 4 and all(d["mode"] == "slice" for d in saved)
    assert all("rng" in d for d in saved)          # per-shard RNG recorded

    for new_width in (2, 1):
        q = ShardedPipeline(CFG, 8, 4, dp_width=new_width, seed=0)
        q.load_shard_state_dicts([dict(d) for d in saved])
        assert q.step == 3 and q.remapped_from == 4
        for i in range(3, 8):
            assert np.array_equal(_tok(q.next_batch()), stream[i]), i


def test_fold_mode_rejects_cross_width_restore():
    p = make_pipeline(CFG, 8, 4, seed=0, num_hosts=2, host_id=0)
    saved = p.state_dict()
    q = make_pipeline(CFG, 8, 4, seed=0, num_hosts=4, host_id=0)
    with pytest.raises(AssertionError, match="width"):
        q.load_state_dict(saved)


def test_repartition_to_non_divisor_width():
    """An elastic shrink can land on ANY survivor count: widths that do
    not divide the global batch get near-equal spans that still tile it."""
    p = ShardedPipeline(CFG, 8, 4, dp_width=4, seed=0)
    full = _tok(p.next_batch())
    p.repartition(3)                      # 4 rows over 3 shards: 1/2/1
    assert p.dp_width == 3 and p.step == 1
    assert [s.host_batch for s in p.shards] == [1, 2, 1]
    spans = [(s.row_lo, s.row_hi) for s in p.shards]
    assert spans[0][0] == 0 and spans[-1][1] == 4
    assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    got = np.concatenate([np.asarray(s.peek_batch(0)["tokens"])
                          for s in p.shards], axis=0)
    assert np.array_equal(got, full)      # merged stream unchanged
    with pytest.raises(AssertionError):
        p.repartition(5)                  # more shards than rows


def test_corrupted_shard_rng_record_is_rejected():
    p = ShardedPipeline(CFG, 8, 4, dp_width=2, seed=0)
    saved = [dict(d) for d in p.shard_state_dicts()]
    saved[1]["rng"] = [123, 456]          # corrupted record
    q = ShardedPipeline(CFG, 8, 4, dp_width=2, seed=0)
    with pytest.raises(AssertionError, match="RNG"):
        q.load_shard_state_dicts(saved)
