"""End-to-end behaviour tests for the paper's system (DeLIA-JAX).

The headline invariant: a DeLIA-protected training run that suffers
fail-stop failures, preemption signals and checkpoint-policy decisions ends
in EXACTLY the state of an unprotected, failure-free run."""
import os
import signal

import jax
import numpy as np

from repro.core import (Dependability, DependabilityConfig, FaultInjector,
                        run_bsp, run_with_recovery)
from repro.data import make_pipeline
from repro.models import get_config
from repro.train import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_full_dependability_stack(tmp_path):
    """Heartbeats on, Young/Daly policy, async+int8 checkpoints, one
    injected fail-stop, then a preemption signal after resume."""
    cfg = get_config("granite-3-8b", tiny=True)
    steps = 12

    # ---- reference (no protection, no failures) ----
    step_fn = jax.jit(make_train_step(cfg, total_steps=steps))
    ref = init_state(cfg, KEY)
    rdata = make_pipeline(cfg, 16, 4)
    for _ in range(steps):
        ref, rm = step_fn(ref, rdata.next_batch())

    # ---- protected run with a crash at step 7 ----
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path),
        policy_mode="every_n", every_n=2,
        async_save=True,
        heartbeat=True, heartbeat_period=0.05,
        signal_detection=True,
    )).start()
    data = make_pipeline(cfg, 16, 4)
    dep.register_local_state(data)
    state = init_state(cfg, KEY)
    injector = FaultInjector()
    injector.schedule_failstop(7)
    state, info = run_with_recovery(dep, step_fn, state, data, steps,
                                    fault_injector=injector, like=state)
    assert info["status"] == "done"
    assert info["restarts"] == 1
    assert not dep.monitor.any_failure()

    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(state["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert float(rm["loss"]) == [h["loss"] for h in info["history"]
                                 if "loss" in h][-1]
    dep.stop()


def test_checkpoint_cost_feeds_young_daly(tmp_path):
    cfg = get_config("gemma-7b", tiny=True)
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=str(tmp_path), policy_mode="young_daly",
        signal_detection=False)).start()
    data = make_pipeline(cfg, 16, 2)
    dep.register_local_state(data)
    state = init_state(cfg, KEY)
    step_fn = jax.jit(make_train_step(cfg))
    state, status, _ = run_bsp(dep, step_fn, state, data, 5)
    assert status == "done"
    assert dep.policy.step_time_s is not None
    assert dep.policy.ckpt_cost_s is not None
    assert dep.policy.interval_steps() >= 1
    dep.stop()
