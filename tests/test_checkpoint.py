"""Checkpoint manager: roundtrip, atomicity, CRC, async, codec, GC,
device-codec fast path, parallel I/O engine, failure propagation."""
import json
import os
import subprocess

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "step": jnp.asarray(7, jnp.int32),
        "params": {"w": jax.random.normal(k, (33, 17)),
                   "b": jnp.zeros((17,))},
        "opt": {"m": {"w": jnp.ones((33, 17)), "b": jnp.zeros((17,))},
                "count": jnp.asarray(3, jnp.int32)},
    }


def _trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_roundtrip_with_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st, {"cursor": 42})
    restored, local = mgr.restore(like=st)
    assert _trees_equal(st, restored)
    assert local == {"cursor": 42}


def test_roundtrip_without_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    restored, _ = mgr.restore()
    assert np.array_equal(np.asarray(restored["params"]["w"]),
                          np.asarray(st["params"]["w"]))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]   # GC keeps 2


def test_async_save_equivalent(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    stats = mgr.save(5, st, blocking=False)
    assert not stats.blocking
    mgr.wait()
    restored, _ = mgr.restore(like=st)
    assert _trees_equal(st, restored)


def test_partial_write_is_invisible(tmp_path):
    """A crash mid-write must never corrupt the readable latest step."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    # simulate a crashed writer: a stale staging dir
    os.makedirs(tmp_path / "step_00000002.tmp.999", exist_ok=True)
    (tmp_path / "step_00000002.tmp.999" / "junk.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(like=st)
    assert _trees_equal(st, restored)


def test_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    final = tmp_path / "step_00000001"
    target = next(p for p in final.iterdir()
                  if p.name.startswith("params.w"))
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        mgr.restore(like=st)


def test_int8_codec_roundtrip_close(tmp_path):
    mgr = CheckpointManager(str(tmp_path), codec="int8")
    st = _state()
    mgr.save(1, st)
    restored, _ = mgr.restore(like=st)
    w0 = np.asarray(st["params"]["w"])
    w1 = np.asarray(restored["params"]["w"])
    # small tensors (<1024 elts) stay lossless; large would be quantized
    assert np.allclose(w0, w1, atol=np.abs(w0).max() / 100)


def test_int8_codec_compresses_large(tmp_path):
    mgr = CheckpointManager(str(tmp_path), codec="int8")
    big = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 1024))}
    stats = mgr.save(1, big)
    assert stats.bytes_written < 256 * 1024 * 4 * 0.5   # ~4x smaller
    restored, _ = mgr.restore(like=big)
    w0, w1 = np.asarray(big["w"]), np.asarray(restored["w"])
    assert np.abs(w0 - w1).max() < np.abs(w0).max() / 64


def test_async_save_failure_propagates_on_wait(tmp_path):
    """Writer-thread errors must surface on the next wait(), then clear."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    # block staging-dir creation: a FILE occupies the staging path
    (tmp_path / f"step_{9:08d}.tmp.{os.getpid()}").write_text("in the way")
    stats = mgr.save(9, st, blocking=False)
    assert not stats.blocking
    with pytest.raises(OSError):
        mgr.wait()
    mgr.wait()  # error consumed; subsequent waits are clean
    # and the manager still works afterwards
    mgr.save(10, st)
    restored, _ = mgr.restore(like=st)
    assert _trees_equal(st, restored)


@pytest.mark.parametrize("io_threads,fsync", [
    (1, "per_file"), (4, "batch"), (4, "none"),
])
def test_roundtrip_across_engine_configs(tmp_path, io_threads, fsync):
    mgr = CheckpointManager(str(tmp_path), io_threads=io_threads, fsync=fsync)
    st = _state()
    mgr.save(3, st, {"cursor": 1})
    restored, local = mgr.restore(like=st)
    assert _trees_equal(st, restored)
    assert local == {"cursor": 1}


def test_device_codec_roundtrip_odd_shapes(tmp_path):
    """On-device int8 path: arbitrary leaf shapes, incl. block counts that
    are not a multiple of the kernel's ROWS tile; small leaves lossless."""
    mgr = CheckpointManager(str(tmp_path), device_codec=True)
    k = jax.random.PRNGKey(3)
    big = {
        # (300*100)=30000 elts -> 118 blocks: nb % 64 != 0
        "a": jax.random.normal(k, (300, 100)),
        # 3-d leaf, 33*17*29=16269 elts -> 64 blocks exactly after pad
        "b": jax.random.normal(jax.random.fold_in(k, 1), (33, 17, 29)) * 40,
        "small": jnp.linspace(-1.0, 1.0, 64),       # < 1 KiB: lossless
        "ints": jnp.arange(5000, dtype=jnp.int32),  # non-float: lossless
    }
    stats = mgr.save(1, big)
    fp32_bytes = sum(np.asarray(v).nbytes for v in big.values())
    assert stats.bytes_written < fp32_bytes * 0.5
    restored, _ = mgr.restore(like=big)
    for name in ("a", "b"):
        w0 = np.asarray(big[name], np.float32)
        w1 = np.asarray(restored[name], np.float32)
        assert w1.shape == w0.shape
        # per-block quantization error bound: amax/127 * 0.5 (+ rounding)
        assert np.abs(w0 - w1).max() <= np.abs(w0).max() / 127.0 * 0.51 + 1e-6
    assert np.array_equal(np.asarray(restored["small"]),
                          np.asarray(big["small"]))
    assert np.array_equal(np.asarray(restored["ints"]),
                          np.asarray(big["ints"]))


def test_device_codec_payload_matches_host_codec(tmp_path):
    """Device-encoded checkpoints decode through the SAME numpy codec and
    produce identical bytes to host-side encoding of the same leaf."""
    from repro.core.codec import DeviceCodec, Int8BlockCodec
    x = jax.random.normal(jax.random.PRNGKey(0), (130, 77))  # 40 blocks
    q, s = DeviceCodec(use_kernel=False).encode(x)
    payload_host, meta = Int8BlockCodec().encode(np.asarray(x))
    nb = meta["blocks"]
    q_host = payload_host[:nb * 256].view(np.int8).reshape(nb, 256)
    s_host = payload_host[nb * 256:].view(np.float32)
    assert np.array_equal(np.asarray(q), q_host)          # int8 bytes exact
    np.testing.assert_allclose(np.asarray(s), s_host,     # XLA may fold
                               rtol=1e-6)                 # /127 -> *(1/127)
    assert DeviceCodec.block_meta(x.shape) == {
        "shape": list(x.shape), "pad": meta["pad"], "blocks": meta["blocks"]}


def test_device_codec_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path), device_codec=True)
    big = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 1024))}
    mgr.save(1, big)
    final = tmp_path / "step_00000001"
    target = next(p for p in final.iterdir() if p.name.startswith("w.s"))
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        mgr.restore(like=big)


def test_bfloat16_leaves_roundtrip(tmp_path):
    """ml_dtypes customs (bf16) must stream + CRC like any other dtype
    (the buffer protocol rejects them; the uint8-view path must not)."""
    st = {"w": jnp.linspace(-2.0, 2.0, 2048).astype(jnp.bfloat16),
          "small": jnp.ones((8,), jnp.bfloat16)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, st)
    restored, _ = mgr.restore(like=st)
    for k in st:
        assert np.array_equal(np.asarray(restored[k]), np.asarray(st[k]))
    # big bf16 leaves also survive the device-codec path (quantized)
    mgr2 = CheckpointManager(str(tmp_path / "dev"), device_codec=True)
    mgr2.save(1, st)
    r2, _ = mgr2.restore(like=st)
    w0 = np.asarray(st["w"], np.float32)
    w1 = np.asarray(r2["w"], np.float32)
    assert np.abs(w0 - w1).max() <= np.abs(w0).max() / 64.0


def test_device_codec_rejects_other_codecs(tmp_path):
    with pytest.raises(ValueError, match="int8"):
        CheckpointManager(str(tmp_path), device_codec=True, codec="zstd")


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        st = _state(key=s)
        mgr.save(s, st)
    r2, _ = mgr.restore(step=2, like=_state())
    assert np.array_equal(np.asarray(r2["params"]["w"]),
                          np.asarray(_state(key=2)["params"]["w"]))


def test_span_gap_raises_instead_of_uninitialized_memory(tmp_path):
    """A lost host manifest used to leave np.empty garbage in the spans it
    covered — silently.  Restore must validate that shard spans exactly
    tile each leaf and raise IOError so restore_latest walks back."""
    from repro.core.io_engine import crc32_array
    mgr = CheckpointManager(str(tmp_path), keep=5)
    st = {"w": jnp.arange(8.0)}
    mgr.save(1, st)
    mgr.save(2, st)
    # simulate the merged-manifest gap: step 2's only shard now claims to
    # cover just half the leaf (as if the other half's manifest was lost)
    man_p = tmp_path / "step_00000002" / "manifest_h0.json"
    man = json.loads(man_p.read_text())
    sh = man["arrays"]["w"]["shards"][0]
    half = np.arange(4.0, dtype=np.float32)
    np.save(tmp_path / "step_00000002" / sh["file"], half)
    sh["spans"] = [[0, 4]]
    sh["crc32"] = crc32_array(half)
    man_p.write_text(json.dumps(man))
    with pytest.raises(IOError, match="cover"):
        mgr.restore(step=2, like=st)
    _, _, got, skipped = mgr.restore_latest(like=st)
    assert got == 1
    assert skipped and skipped[0][0] == 2


def test_overlapping_spans_raise(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = {"w": jnp.arange(8.0)}
    mgr.save(1, st)
    man_p = tmp_path / "step_00000001" / "manifest_h0.json"
    man = json.loads(man_p.read_text())
    sh = dict(man["arrays"]["w"]["shards"][0])
    sh["spans"] = [[4, 8]]               # second shard overlapping [0,8)
    man["arrays"]["w"]["shards"].append(sh)
    man_p.write_text(json.dumps(man))
    with pytest.raises(IOError, match="overlap"):
        mgr.restore(step=1, like=st)


def test_replicated_identical_spans_dedupe_cleanly(tmp_path):
    """Two host manifests carrying the SAME span (a replicated leaf) are
    legitimate — dedupe, don't flag as overlap."""
    mgr = CheckpointManager(str(tmp_path))
    st = {"w": jnp.arange(8.0)}
    mgr.save(1, st)
    man_p = tmp_path / "step_00000001" / "manifest_h0.json"
    man = json.loads(man_p.read_text())
    man["arrays"]["w"]["shards"].append(
        dict(man["arrays"]["w"]["shards"][0]))
    man_p.write_text(json.dumps(man))
    restored, _ = mgr.restore(step=1, like=st)
    assert np.array_equal(np.asarray(restored["w"]), np.asarray(st["w"]))


# ---- stale staging-dir sweep (crashed async writers) ----

def test_stale_staging_swept_on_init_and_gc(tmp_path):
    """Crashed writers leak step_<n>.tmp.<pid> dirs forever unless the
    manager reclaims them: on init, and at every GC."""
    stale = tmp_path / "step_00000009.tmp.999999983"    # ESRCH pid: dead
    os.makedirs(stale)
    (stale / "junk.npy").write_bytes(b"xx")
    mgr = CheckpointManager(str(tmp_path), keep=1)
    assert not stale.exists()                            # swept on init
    os.makedirs(stale)
    st = _state()
    mgr.save(1, st)
    mgr.save(2, st)                                      # triggers _gc
    assert not stale.exists()                            # swept at GC
    restored, _ = mgr.restore(like=st)
    assert _trees_equal(st, restored)


def test_live_foreign_staging_not_swept(tmp_path):
    """A staging dir owned by another LIVE process (a co-hosted writer
    mid-save) must survive the sweep."""
    live = subprocess.Popen(["sleep", "30"])
    try:
        peer = tmp_path / f"step_00000003.tmp.{live.pid}"
        os.makedirs(peer)
        CheckpointManager(str(tmp_path))
        assert peer.exists()
    finally:
        live.kill()
        live.wait()


# ---- local-SCOPE shard files (elastic failover loop) ----

def test_local_shards_saved_as_own_files_and_restored_in_order(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    shards = [{"shard": k, "step": 5, "cursor": 10 + k} for k in range(3)]
    mgr.save(5, st, {"step": 5}, local_shards=shards)
    final = os.path.join(str(tmp_path), "step_00000005")
    files = sorted(f for f in os.listdir(final) if f.startswith("local_s"))
    assert files == ["local_s00000.json", "local_s00001.json",
                     "local_s00002.json"]
    got = mgr.restore_local_shards(5)
    assert got == shards                   # ordered by shard index
    # host-scope local state still rides alongside
    _, local = mgr.restore(like=st, step=5)
    assert local == {"step": 5}


def test_restore_local_shards_empty_for_legacy_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st, {"cursor": 2})
    assert mgr.restore_local_shards(1) == []


def test_local_shards_survive_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    shards = [{"shard": k, "v": k * k} for k in range(4)]
    mgr.save(2, st, None, local_shards=shards, blocking=False)
    mgr.wait()
    assert mgr.restore_local_shards(2) == shards


def test_manifest_records_local_shard_indices(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state(), None,
             local_shards=[{"shard": 1, "x": 0}, {"shard": 0, "x": 1}])
    with open(os.path.join(str(tmp_path), "step_00000003",
                           "manifest_h0.json")) as f:
        man = json.load(f)
    assert man["local_shards"] == [1, 0]


def test_corrupt_local_shard_walks_back_like_any_corrupt_shard(tmp_path):
    """A truncated local_s<k>.json must not kill the restore: with
    with_local_shards the walk-back treats it like a CRC failure and
    falls back to the previous checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    for s in (1, 2):
        mgr.save(s, st, {"step": s},
                 local_shards=[{"shard": 0, "step": s}])
    bad = os.path.join(str(tmp_path), "step_00000002", "local_s00000.json")
    with open(bad, "w") as f:
        f.write('{"shard": 0, "st')           # truncated mid-write
    state, local, shards, got, skipped = mgr.restore_latest(
        like=st, with_local_shards=True)
    assert got == 1                           # walked back past step 2
    assert shards == [{"shard": 0, "step": 1}]
    assert skipped and skipped[0][0] == 2
