"""Checkpoint manager: roundtrip, atomicity, CRC, async, codec, GC."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CheckpointManager


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "step": jnp.asarray(7, jnp.int32),
        "params": {"w": jax.random.normal(k, (33, 17)),
                   "b": jnp.zeros((17,))},
        "opt": {"m": {"w": jnp.ones((33, 17)), "b": jnp.zeros((17,))},
                "count": jnp.asarray(3, jnp.int32)},
    }


def _trees_equal(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_roundtrip_with_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st, {"cursor": 42})
    restored, local = mgr.restore(like=st)
    assert _trees_equal(st, restored)
    assert local == {"cursor": 42}


def test_roundtrip_without_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    restored, _ = mgr.restore()
    assert np.array_equal(np.asarray(restored["params"]["w"]),
                          np.asarray(st["params"]["w"]))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]   # GC keeps 2


def test_async_save_equivalent(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    stats = mgr.save(5, st, blocking=False)
    assert not stats.blocking
    mgr.wait()
    restored, _ = mgr.restore(like=st)
    assert _trees_equal(st, restored)


def test_partial_write_is_invisible(tmp_path):
    """A crash mid-write must never corrupt the readable latest step."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    # simulate a crashed writer: a stale staging dir
    os.makedirs(tmp_path / "step_00000002.tmp.999", exist_ok=True)
    (tmp_path / "step_00000002.tmp.999" / "junk.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(like=st)
    assert _trees_equal(st, restored)


def test_crc_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    final = tmp_path / "step_00000001"
    target = next(p for p in final.iterdir()
                  if p.name.startswith("params.w"))
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        mgr.restore(like=st)


def test_int8_codec_roundtrip_close(tmp_path):
    mgr = CheckpointManager(str(tmp_path), codec="int8")
    st = _state()
    mgr.save(1, st)
    restored, _ = mgr.restore(like=st)
    w0 = np.asarray(st["params"]["w"])
    w1 = np.asarray(restored["params"]["w"])
    # small tensors (<1024 elts) stay lossless; large would be quantized
    assert np.allclose(w0, w1, atol=np.abs(w0).max() / 100)


def test_int8_codec_compresses_large(tmp_path):
    mgr = CheckpointManager(str(tmp_path), codec="int8")
    big = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 1024))}
    stats = mgr.save(1, big)
    assert stats.bytes_written < 256 * 1024 * 4 * 0.5   # ~4x smaller
    restored, _ = mgr.restore(like=big)
    w0, w1 = np.asarray(big["w"]), np.asarray(restored["w"])
    assert np.abs(w0 - w1).max() < np.abs(w0).max() / 64


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 3):
        st = _state(key=s)
        mgr.save(s, st)
    r2, _ = mgr.restore(step=2, like=_state())
    assert np.array_equal(np.asarray(r2["params"]["w"]),
                          np.asarray(_state(key=2)["params"]["w"]))
