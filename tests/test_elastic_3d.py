"""Elastic 3D (data, model, expert) mesh: grid factorization, graceful
MoE expert degradation, mesh-aware serve failover, and the E2E
survive-a-host-kill acceptance scenario (docs/elastic.md "3D meshes").

Fast tests run on the default single CPU device (grid math, MoE layer
math, the control-plane simulator, router bookkeeping).  The E2E runs in
a subprocess with --xla_force_host_platform_device_count=8 like the other
elastic suites.
"""
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.elastic import (MeshSpec, NoLegalGridError, best_grid3d,
                                largest_grid)
from repro.layers.moe import (drop_experts, moe_apply, moe_init,
                              router_probs, _capacity)
from repro.models import get_config

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SCENARIOS = os.path.join(ROOT, "scenarios")


# ---------------------------------------------------------------------------
# grid factorization
# ---------------------------------------------------------------------------

def _spec(data=2, model=2, expert=2, legal=(1, 2), experts=8):
    return MeshSpec(data=data, model=model, expert=expert,
                    legal_model=legal, num_experts=experts)


def test_best_grid3d_full_grid():
    assert best_grid3d(8, _spec()) == (2, 2, 2)


def test_best_grid3d_degrades_ep_before_dp_before_tp():
    spec = _spec()
    # 6 devices: dropping ep (2 -> 1) keeps all 6 busy at full tp
    assert best_grid3d(6, spec) == (3, 2, 1)
    # 4 devices: the desired grid minus one dp replica
    assert best_grid3d(4, spec) == (2, 2, 1)
    # 2 devices: tp survives to the end — ep and dp both gone
    assert best_grid3d(2, spec) == (1, 2, 1)
    assert best_grid3d(1, spec) == (1, 1, 1)


def test_best_grid3d_every_grid_is_legal():
    """Sweep: the chosen grid always satisfies every per-axis constraint
    and never wastes devices when a fuller legal grid exists."""
    for experts in (1, 2, 4, 8):
        spec = _spec(experts=experts)
        for n in range(1, 17):
            dp, tp, ep = best_grid3d(n, spec)
            assert dp * tp * ep <= n
            assert tp in spec.legal_model
            if experts:
                assert experts % ep == 0
            assert ep <= max(spec.expert, 1)


def test_meshspec_from_config_derives_legal_widths():
    cfg = get_config("mixtral-8x7b", tiny=True)
    spec = MeshSpec.from_config(cfg, data=2, model=2, expert=2)
    assert spec.num_experts == cfg.num_experts == 4
    assert 2 in spec.legal_model
    # dp must divide d_model (the FSDP dim): 64 -> 2 legal, 3 not
    assert 2 in spec.legal_data and 3 not in spec.legal_data
    assert spec.size == 8 and spec.shape() == (2, 2, 2)
    assert spec.with_experts(2).num_experts == 2


def test_best_grid3d_respects_legal_dp_widths():
    """A dp the checkpoint cannot re-partition to is no grid at all: with
    d_model-style legality the factorization idles devices rather than
    picking dp=3."""
    spec = _spec(experts=2, legal=(1, 2))
    constrained = MeshSpec(data=2, model=2, expert=2, legal_model=(1, 2),
                           legal_data=(1, 2, 4), num_experts=2)
    assert best_grid3d(6, spec) == (3, 2, 1)          # unconstrained
    assert best_grid3d(6, constrained) == (2, 2, 1)   # 2 devices idle
    assert best_grid3d(8, constrained) == (2, 2, 2)   # full grid untouched


def test_largest_grid_rejects_illegal_width_with_legal_list():
    # constrained: no legal width divides 6 -> clear error, not a bad grid
    with pytest.raises(NoLegalGridError, match="no legal width divides 6"):
        largest_grid(6, 4, legal=(4,))
    # a legal grid exists but only ABOVE model_axis: the error lists it
    with pytest.raises(NoLegalGridError, match=r"\(1, 6\)"):
        largest_grid(6, 5, legal=(6,))
    # unconstrained: degrade to the largest divisor instead of guessing
    assert largest_grid(6, 4) == (2, 3)
    assert largest_grid(6, 3, legal=(1, 2)) == (3, 2)


# ---------------------------------------------------------------------------
# MoE graceful degradation (satellite: distribution / capacity / bit-exact)
# ---------------------------------------------------------------------------

E, D, FF = 4, 16, 32
_KEY = jax.random.PRNGKey(0)


def _moe(dead=(), num_experts=E, params=None, x=None):
    p = params if params is not None else moe_init(_KEY, D, FF, num_experts,
                                                   jnp.float32)
    xx = x if x is not None else jax.random.normal(jax.random.PRNGKey(1),
                                                   (2, 6, D))
    y, aux = moe_apply(p, xx, num_experts=num_experts, k=2,
                       capacity_factor=1.25, act=jax.nn.silu,
                       compute_dtype=jnp.float32, dead_experts=dead)
    return p, xx, np.asarray(y), np.asarray(aux)


def test_dead_router_is_proper_distribution():
    p, x, _, _ = _moe()
    logits = np.asarray(x, np.float32) @ np.asarray(p["router"])
    for dead in [(1,), (0, 2), (3,), (0, 1, 2)]:
        probs = np.asarray(router_probs(jnp.asarray(logits), E, dead))
        assert np.allclose(probs.sum(-1), 1.0, atol=1e-6)
        assert np.all(probs[..., list(dead)] == 0.0)   # exactly zero mass
        live = [e for e in range(E) if e not in dead]
        assert np.all(probs[..., live] > 0.0)


def test_dead_experts_bitexact_vs_survivor_model():
    """Degraded full-size model == a model holding just the survivor
    experts, bit for bit (outputs AND aux loss)."""
    for dead in [(1,), (0, 2), (3,)]:
        p, x, y1, a1 = _moe(dead=dead)
        p2 = drop_experts(p, dead)
        _, _, y2, a2 = _moe(num_experts=E - len(dead), params=p2, x=x)
        assert np.array_equal(y1, y2), dead
        assert np.array_equal(a1, a2), dead


def test_dead_experts_capacity_recomputes_from_live_count():
    # capacity is per live expert: fewer survivors -> bigger slices
    S, k, cf = 6, 2, 1.25
    assert _capacity(S, 4, k, cf) < _capacity(S, 2, k, cf)
    # and k clamps to the live count when fewer survive than top-k
    p, x, y, _ = _moe(dead=(0, 1, 2))          # one live expert, k=2 -> 1
    assert np.isfinite(y).all()


def test_all_experts_dead_raises():
    with pytest.raises(ValueError, match="all .* experts dead"):
        _moe(dead=(0, 1, 2, 3))


def test_dead_expert_out_of_range_raises():
    with pytest.raises(ValueError, match="out of range"):
        _moe(dead=(7,))


def test_drop_experts_slices_every_leaf():
    p = moe_init(_KEY, D, FF, E, jnp.float32)
    p2 = drop_experts(p, (1, 3))
    assert p2["router"].shape == (D, 2)
    assert p2["w_in"].shape == (2, D, FF)
    assert p2["w_gate"].shape == (2, D, FF)
    assert p2["w_out"].shape == (2, FF, D)
    np.testing.assert_array_equal(np.asarray(p2["w_in"][0]),
                                  np.asarray(p["w_in"][0]))
    np.testing.assert_array_equal(np.asarray(p2["w_in"][1]),
                                  np.asarray(p["w_in"][2]))


# ---------------------------------------------------------------------------
# control-plane simulator: axis-aware 3D coordinates + scenario replay
# ---------------------------------------------------------------------------

def test_sim_host_coords_expert_major():
    """host -> (dp, tp, ep) coordinates follow survivor_mesh3d's
    expert-major placement: a host's contiguous devices sit inside ONE
    expert slice."""
    from repro.chaos.sim import ControlPlaneSim
    spec = _spec(experts=8)
    sim = ControlPlaneSim(4, devices_per_host=2, mesh_spec=spec)
    coords = sim.host_coords()
    # 8 devices -> (2,2,2); hosts 0,1 (devices 0-3) are expert slice 0,
    # hosts 2,3 (devices 4-7) are expert slice 1
    assert coords == {0: (0, 0, 0), 1: (1, 0, 0),
                      2: (0, 0, 1), 3: (1, 0, 1)}
    # losing host 1 re-factors to (3,2,1): every survivor in slice 0
    assert sim.host_coords(members=[0, 2, 3]) == {
        0: (0, 0, 0), 2: (1, 0, 0), 3: (2, 0, 0)}


def test_sim_axis_loss_replay_invariants_green():
    """The acceptance trace: kill one host of a tp group inside an SDC
    storm; the shared invariant suite (including the new legal-3d-grid
    check) must pass and the mesh must degrade ep first."""
    from repro.chaos.scenario import Scenario
    from repro.chaos.sim import ControlPlaneSim
    sc = Scenario.from_json(os.path.join(SCENARIOS, "axis_loss.json"))
    spec = _spec(experts=8)
    sim = ControlPlaneSim(4, devices_per_host=2, mesh_spec=spec)
    rep = sim.run(sc)
    assert all(r.passed for r in rep.invariants), rep.invariants
    assert any(r.name == "legal-3d-grid" for r in rep.invariants)
    grids = [(m["dp"], m["mp"], m["ep"]) for m in rep.mesh_history]
    assert grids[0] == (2, 2, 2)
    assert (3, 2, 1) in grids             # ep dropped before tp
    assert grids[-1] == (2, 2, 2)         # rejoin restores the full grid


def test_sim_axis_loss_replay_at_scale():
    """Same trace, 1000 virtual hosts — the device-free validation the
    tentpole names."""
    from repro.chaos.scenario import Scenario
    from repro.chaos.sim import ControlPlaneSim
    sc = Scenario.from_json(os.path.join(SCENARIOS, "axis_loss.json"))
    spec = MeshSpec(data=500, model=2, expert=8, legal_model=(1, 2),
                    num_experts=64)
    sim = ControlPlaneSim(1000, devices_per_host=2, mesh_spec=spec)
    rep = sim.run(sc)
    assert all(r.passed for r in rep.invariants), rep.invariants


# ---------------------------------------------------------------------------
# mesh-aware serve router: multi-host tp replica fails as a unit
# ---------------------------------------------------------------------------

def test_router_maps_hosts_to_replicas_and_drains_once():
    from repro.serve import ServeFns
    from repro.serve.router import ReplicaRouter
    from repro.models import init_params

    cfg = get_config("granite-3-8b", tiny=True)
    params = init_params(cfg, _KEY)
    fns = ServeFns(cfg, num_slots=2, max_len=16)
    router = ReplicaRouter(fns, hosts_per_replica=2)
    r0 = router.add_replica(params)
    r1 = router.add_replica(params)
    assert r0.hosts == (0, 1) and r1.hosts == (2, 3)

    # both hosts of replica 1 detected dead -> surfaces the replica ONCE
    router._latch(2)
    router._latch(3)
    assert router.take_detected() == [1]
    assert router.take_detected() == []   # drained

    drained = router.fail_replica(r1, "host-loss")
    assert not r1.healthy
    assert router.fail_replica(r1, "again") == []   # unit drain: once
    assert [e[0] for e in router.events] == ["replica_failed"]
    assert drained == []                  # nothing in flight in this unit
    router.shutdown()


@pytest.mark.slow
def test_serve_multihost_replica_unit_drain_token_identical():
    """Kill ONE host of a 2-host tp replica mid-decode: the whole replica
    fails over as a unit (exactly one drain event), zero requests dropped,
    retried streams token-identical to the uninterrupted reference."""
    from repro.serve import ServeEngine
    from repro.models import init_cache, init_params
    from repro.train import make_decode_step, make_prefill_step

    cfg = get_config("granite-3-8b", tiny=True)
    params = init_params(cfg, _KEY)
    max_len, gen = 32, 16
    prompts = [list(range(5 + i, 10 + i)) for i in range(4)]

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    ref = []
    for p in prompts:
        tok, row = prefill(params, {"tokens": jnp.asarray(p, jnp.int32)[None]},
                           init_cache(cfg, 1, max_len))
        s = [int(tok[0])]
        for _ in range(gen - 1):
            tok, row = decode(params, {"tokens": tok[:, None]}, row)
            s.append(int(tok[0]))
        ref.append(s)

    period = 0.05
    eng = ServeEngine(cfg, params, num_replicas=2, slots_per_replica=2,
                      max_len=max_len, fault_tolerant=True,
                      heartbeat_period=period, heartbeat_timeout_factor=6.0,
                      hosts_per_replica=2)
    victim = eng.router.replicas[1]
    assert len(victim.hosts) == 2 and len(victim.emitters) == 2
    rids = [eng.submit(p, gen) for p in prompts]
    steps = 0
    while not eng.scheduler.all_done():
        eng.step()
        steps += 1
        if steps == 3:
            victim.emitters[1].pause()    # ONE host of the tp group dies
            time.sleep(10 * period)
    res = eng.results()
    fails = [e for e in eng.events if e["event"] == "replica_failed"]
    eng.shutdown()
    assert len(fails) == 1                # unit drain: one incident
    assert not victim.healthy
    assert eng.scheduler.failed_rids == []
    assert len(res) == len(prompts)
    for rid, r in zip(rids, ref):
        assert res[rid] == r, f"retried stream diverged for rid {rid}"


# ---------------------------------------------------------------------------
# E2E: Mixtral-style MoE on a (2,2,2) mesh survives killing one host
# ---------------------------------------------------------------------------

def _run(script: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.mark.slow
def test_e2e_3d_mesh_survives_host_kill(tmp_path):
    """The acceptance scenario: mixtral-tiny on (data=2, model=2, expert=2)
    over 4 hosts x 2 devices.  Kill host 1 (one host of a tp group, one
    half of expert slice 0): run_elastic reshards to the legal survivor
    grid (3, 2, 1), drops the broken slice's experts, renormalizes the
    router, and the merged trajectory matches an uninterrupted reference
    that degrades the same experts at the same step."""
    _run(f"""
    import dataclasses, time
    import jax
    from repro.chaos import invariants as inv
    from repro.core import (Dependability, DependabilityConfig,
                            HeartbeatEmitter, MeshSpec, run_elastic)
    from repro.data import ShardedPipeline
    from repro.launch.mesh import host_device_map
    from repro.models import get_config
    from repro.sharding.api import resolve
    from repro.sharding.rules import state_specs
    from repro.train import init_state, make_train_step

    cfg = get_config("mixtral-8x7b", tiny=True)
    KEY = jax.random.PRNGKey(0)
    PERIOD = 0.05
    STEPS = 8
    spec = MeshSpec.from_config(cfg, data=2, model=2, expert=2)

    def shardings_for(mesh, dead=()):
        axes = dict(zip(mesh.axis_names, mesh.devices.shape))
        tp, ep = axes.get("model", 1), axes.get("expert", 1)
        specs = state_specs(cfg, tp, moe_ep=(ep if ep > 1 else False))
        return jax.tree.map(lambda s: resolve(s, mesh), specs,
                            is_leaf=lambda x: x.__class__.__name__ ==
                            "PartitionSpec")

    def make_step(mesh, dead=()):
        c = dataclasses.replace(cfg, dead_experts=tuple(dead))
        return jax.jit(make_train_step(c, total_steps=STEPS),
                       out_shardings=(shardings_for(mesh, dead), None))

    hosts = host_device_map(4)            # 4 hosts x 2 devices
    dep = Dependability(DependabilityConfig(
        checkpoint_dir=r"{tmp_path}", policy_mode="every_n", every_n=1,
        heartbeat=True, heartbeat_period=PERIOD,
        heartbeat_timeout_factor=5.0, signal_detection=False,
        monitor_hosts=4), host_id=0, num_hosts=1).start()
    ems = {{h: HeartbeatEmitter(h, dep.monitor.addr, PERIOD).start()
           for h in (1, 2, 3)}}

    data = ShardedPipeline(cfg, 4, 12, dp_width=2)
    state = init_state(cfg, KEY)
    template = jax.eval_shape(lambda: init_state(cfg, KEY))

    paused = {{"done": False}}
    def on_metrics(s, rec):
        if s == 3 and not paused["done"]:
            paused["done"] = True
            ems[1].pause()                # host 1 dies: beats stop
            time.sleep(6 * PERIOD)

    state, info = run_elastic(dep, make_step, state, data, STEPS,
                              host_devices=hosts, mesh_spec=spec,
                              degrade_experts=True, like=template,
                              shardings_fn=shardings_for,
                              on_metrics=on_metrics)
    assert info["status"] == "done"
    ev = info["events"]
    assert [e.kind for e in ev] == ["shrink"], ev
    assert ev[0].hosts == (1,)
    # 6 survivors, but dp=3 cannot re-partition the FSDP dim (d_model=64):
    # the best LEGAL grid idles two devices instead of wedging restore
    assert (ev[0].dp, ev[0].tp, ev[0].ep) == (2, 2, 1), ev
    deg = [h for h in info["history"]
           if str(h.get("event", "")).startswith("degraded_experts")]
    assert len(deg) == 1, info["history"]
    # host 1 held half of expert slice 0 -> experts 0,1 lost, 2 live
    assert deg[0]["event"] == "degraded_experts:0,1:live=2", deg

    # the manifest records the survivor grid for restart/reshard
    meta = dep.manager.manifest_meta(dep.manager.latest_step())
    assert meta == {{"dp": 2, "tp": 2, "ep": 1, "moe_ep": 1,
                    "dead_experts": [0, 1]}}, meta

    # reference: uninterrupted single-device run that degrades the SAME
    # experts at the SAME step boundary
    fail_step = deg[0]["step"]
    ref_data = ShardedPipeline(cfg, 4, 12, dp_width=1)
    live_step = jax.jit(make_train_step(cfg, total_steps=STEPS))
    dead_cfg = dataclasses.replace(cfg, dead_experts=(0, 1))
    dead_step = jax.jit(make_train_step(dead_cfg, total_steps=STEPS))
    ref = init_state(cfg, KEY)
    ref_losses = []
    for s in range(1, STEPS + 1):
        step_fn = live_step if s <= fail_step else dead_step
        ref, m = step_fn(ref, ref_data.next_batch())
        ref_losses.append(float(m["loss"]))

    losses = [h["loss"] for h in info["history"] if "loss" in h]
    assert bool(inv.check_no_lost_steps(info["history"], STEPS))
    tm = inv.check_trajectory_match(losses, ref_losses, tol=0.15)
    assert bool(tm), tm
    for em in ems.values():
        em.stop()
    dep.stop()
    print("3D mesh host-kill OK", losses[-1], ref_losses[-1])
    """, devices=8)

    # ...and the same failure shape replays device-free in the simulator
    from repro.chaos.scenario import Scenario
    from repro.chaos.sim import ControlPlaneSim
    cfg = get_config("mixtral-8x7b", tiny=True)
    spec = MeshSpec.from_config(cfg, data=2, model=2, expert=2)
    sc = Scenario.from_json(os.path.join(SCENARIOS, "axis_loss.json"))
    rep = ControlPlaneSim(4, devices_per_host=2, mesh_spec=spec).run(sc)
    assert all(r.passed for r in rep.invariants), rep.invariants
