"""int8 codec + error-feedback gradient compression properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; see requirements-dev.txt
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.codec import CODECS
from repro.optim import dequantize_int8, ef_state_init, quantize_int8

arrays = hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                 max_side=64),
                    elements=st.floats(-1e4, 1e4, width=32))


@given(x=arrays)
@settings(max_examples=60, deadline=None)
def test_numpy_codec_roundtrip_bounded(x):
    codec = CODECS["int8"]
    payload, meta = codec.encode(x)
    y = codec.decode(payload, meta)
    assert y.shape == x.shape
    # per-block bound: |err| <= blockmax/127 * 0.5 (+ tiny eps)
    err = np.abs(y - x)
    bound = max(np.abs(x).max() / 127.0, 1e-9) * 0.51 + 1e-6
    assert err.max() <= bound


@given(x=arrays)
@settings(max_examples=40, deadline=None)
def test_jnp_codec_matches_numpy_codec(x):
    codec = CODECS["int8"]
    payload, meta = codec.encode(x)
    y_np = codec.decode(payload, meta)
    q, s, m = quantize_int8(jnp.asarray(x))
    y_jnp = np.asarray(dequantize_int8(q, s, m))
    np.testing.assert_allclose(y_np, y_jnp, atol=1e-5, rtol=1e-5)


def test_error_feedback_unbiased_over_time():
    """EF: the running sum of compressed gradients converges to the true
    running sum (residual stays bounded)."""
    from repro.optim.compress import dequantize_int8 as dq
    from repro.optim.compress import quantize_int8 as qz

    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    ef = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        g_eff = g_true + ef
        q, s, m = qz(g_eff)
        deq = dq(q, s, m)
        ef = g_eff - deq
        acc = acc + deq
    # after T steps, acc ~ T * g_true with bounded residual
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g_true),
                               atol=np.abs(g_true).max() / 100)
    assert np.abs(np.asarray(ef)).max() <= np.abs(np.asarray(g_true)).max() \
        / 127 + 1e-5


def test_compressed_psum_in_shard_map():
    """compressed_psum under shard_map equals the plain mean within
    quantization tolerance (single device: group of 1)."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.optim import compressed_psum, ef_state_init

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    grads = {"w": jnp.linspace(-2, 2, 256)}
    ef = ef_state_init(grads)

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map

    def f(g, e):
        return compressed_psum(g, e, "data")

    kws = dict(mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    try:
        sm = shard_map(f, check_vma=False, **kws)
    except TypeError:
        sm = shard_map(f, check_rep=False, **kws)
    red, new_ef = sm(grads, ef)
    np.testing.assert_allclose(np.asarray(red["w"]),
                               np.asarray(grads["w"]), atol=2e-2)
