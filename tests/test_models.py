"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + NaN assertions, and decode-path equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_pipeline
from repro.models import forward, get_config, init_cache, init_params, \
    list_archs
from repro.train import init_state, make_train_step

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, key=KEY):
    b = {}
    if cfg.embedding_inputs:
        b["embeddings"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            cfg.dtype)
    else:
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b["targets"] = jax.random.randint(jax.random.fold_in(key, 1), (B, S),
                                      0, cfg.vocab_size)
    if cfg.mrope_sections:
        b["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch, tiny=True)
    params = init_params(cfg, KEY)
    B, S = 2, 16
    logits, cache, aux = forward(cfg, params, _batch(cfg, B, S), mode="train")
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert cache is None
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, tiny=True)
    state = init_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, total_steps=10))
    data = make_pipeline(cfg, seq_len=16, global_batch=2)
    s1, m1 = step(state, data.next_batch())
    s2, m2 = step(s1, data.next_batch())
    assert int(s2["step"]) == 2
    for mname in ("loss", "grad_norm"):
        assert np.isfinite(float(m1[mname])), mname
        assert np.isfinite(float(m2[mname])), mname


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if get_config(a, tiny=True).has_decode])
def test_prefill_decode_matches_full_forward(arch):
    cfg = get_config(arch, tiny=True)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no cap drops
    params = init_params(cfg, KEY)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    batch.pop("targets")
    full, _, _ = forward(cfg, params, batch, mode="train")

    pre = {k: (v[:, :, :S - 1] if k == "positions" else v[:, :S - 1])
           for k, v in batch.items()}
    cache = init_cache(cfg, B, S)
    _, cache, _ = forward(cfg, params, pre, mode="prefill", cache=cache)
    dec = {k: (v[:, :, S - 1:S] if k == "positions" else v[:, S - 1:S])
           for k, v in batch.items()}
    dl, cache2, _ = forward(cfg, params, dec, mode="decode", cache=cache)
    assert int(cache2["index"]) == S
    # bf16 logits resolve to ~2^-7 ulps around |x|~2; a few ulps of
    # prefill/decode divergence is expected on CPU XLA
    tol = 5e-2 if dl.dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(dl[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=tol, rtol=tol)


def test_encoder_has_no_decode():
    cfg = get_config("hubert-xlarge", tiny=True)
    assert not cfg.has_decode


def test_long_context_applicability():
    from repro.launch.shapes import cell_applicable
    ok_archs, skip_archs = [], []
    for a in ARCHS:
        ok, _ = cell_applicable(get_config(a), "long_500k")
        (ok_archs if ok else skip_archs).append(a)
    assert set(ok_archs) == {"falcon-mamba-7b", "recurrentgemma-2b",
                             "mixtral-8x7b"}


def test_loss_decreases_tiny_lm():
    cfg = get_config("granite-3-8b", tiny=True)
    state = init_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, peak_lr=1e-2, warmup_steps=2,
                                   total_steps=30))
    data = make_pipeline(cfg, seq_len=32, global_batch=4)
    first = last = None
    batch = data.next_batch()  # overfit one batch
    for i in range(15):
        state, m = step(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first, (first, last)


def test_microbatch_equivalence():
    cfg = get_config("gemma-7b", tiny=True)
    data = make_pipeline(cfg, seq_len=16, global_batch=4)
    batch = data.next_batch()
    s0 = init_state(cfg, KEY)
    s1, m1 = jax.jit(make_train_step(cfg, microbatches=1))(s0, batch)
    s0b = init_state(cfg, KEY)
    s2, m2 = jax.jit(make_train_step(cfg, microbatches=2))(s0b, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5,
                                   rtol=1e-4)


def test_analytic_param_count_matches_init():
    for arch in ARCHS:
        cfg = get_config(arch, tiny=True)
        shapes = jax.eval_shape(lambda c=cfg: init_params(c, KEY))
        total = 0
        for leaf in jax.tree.leaves(shapes):
            n = 1
            for d in leaf.shape:
                n *= d
            total += n
        analytic = cfg.num_params()
        # padded vocab + padded heads make init >= analytic; within 30%
        assert total >= analytic * 0.7, arch
        assert total <= analytic * 1.6 + 2 * cfg.padded_vocab * cfg.d_model, \
            arch
